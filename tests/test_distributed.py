"""Distributed-parity tests: run in a SUBPROCESS with 8 forced host devices
(so the main pytest process keeps its single real device), asserting that

  * the sharded (2x4 mesh FSDP x TP) train step produces the same loss and
    updated params as the unsharded step,
  * the shard_map MoE path matches the no-mesh dispatch bit-for-bit in
    routing decisions,
  * decode with sharded caches matches unsharded decode.
"""
import json
import os
import pathlib
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCH_SPECS
from repro.models import transformer as tfm
from repro.models.transformer import RunCtx
from repro.optim import OptimizerConfig
from repro.optim.adamw import opt_state_sharding
from repro.runtime.sharding import batch_sharding, build_rules, cache_sharding
from repro.runtime.steps import StepConfig, init_train_state, make_train_step, make_serve_step
from jax.sharding import NamedSharding, PartitionSpec

results = {}
mesh = jax.make_mesh((2, 4), ("data", "model"))

for arch_id in ["smollm-135m", "phi3.5-moe-42b-a6.6b", "mamba2-370m",
                "zamba2-1.2b", "deepseek-v2-236b"]:
    cfg = ARCH_SPECS[arch_id].smoke
    step_cfg = StepConfig(n_micro=1, remat="none",
                          optimizer=OptimizerConfig(learning_rate=1e-3,
                                                    warmup_steps=1,
                                                    total_steps=10))
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              ((4, 16, cfg.n_codebooks) if cfg.n_codebooks
                               else (4, 16)), 0, cfg.vocab_size)
    batch = {"inputs": toks, "targets": toks}
    if cfg.vision_tokens:
        batch["image_embeds"] = jax.random.normal(
            key, (4, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)

    # -- unsharded reference ------------------------------------------------
    state0, axes = init_train_state(key, cfg, step_cfg)
    ref_step = jax.jit(make_train_step(cfg, step_cfg))
    ref_state, ref_m = ref_step(jax.tree.map(lambda x: x, state0), batch)

    # -- sharded --------------------------------------------------------------
    rules = build_rules(cfg, mesh)
    psh = rules.param_sharding(axes)
    rep = NamedSharding(mesh, PartitionSpec())
    state_sh = {"params": psh,
                "opt": opt_state_sharding(psh, state0["opt"], mesh),
                "step": rep}
    bsh = batch_sharding(rules, batch)
    state_p = jax.device_put(state0, state_sh)
    batch_p = jax.device_put(batch, bsh)
    with mesh:
        sh_step = jax.jit(make_train_step(cfg, step_cfg, rules),
                          in_shardings=(state_sh, bsh),
                          out_shardings=(state_sh, None))
        sh_state, sh_m = sh_step(state_p, batch_p)

    dloss = abs(float(ref_m["loss"]) - float(sh_m["loss"]))
    dg = abs(float(ref_m["grad_norm"]) - float(sh_m["grad_norm"])) \
        / max(float(ref_m["grad_norm"]), 1e-9)
    # updated params parity (max over leaves of max-abs diff)
    dmax = 0.0
    for a, b in zip(jax.tree.leaves(ref_state["params"]),
                    jax.tree.leaves(sh_state["params"])):
        dmax = max(dmax, float(jnp.max(jnp.abs(a - np.asarray(b)))))
    results[arch_id] = {"dloss": dloss, "dgrad": dg, "dparam": dmax,
                        "route_limited": bool(cfg.route_group_limit)}

# -- decode parity on one arch with sharded caches ----------------------------
cfg = ARCH_SPECS["h2o-danube-3-4b"].smoke
params, axes = tfm.init_lm(jax.random.PRNGKey(0), cfg)
ctx = RunCtx()
toks = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0, cfg.vocab_size)
_, cache = tfm.prefill(params, toks, cfg, ctx, max_len=16)
tok_new = toks[:, :1]
ref_logits, _ = tfm.decode_step(params, cache, tok_new, cfg, ctx)

rules = build_rules(cfg, mesh)
psh = rules.param_sharding(axes)
csh = cache_sharding(rules, cache, cfg)
with mesh:
    serve = jax.jit(make_serve_step(cfg, StepConfig(), rules, greedy=False),
                    in_shardings=(psh, csh, batch_sharding(rules, tok_new)))
    sh_logits, _ = serve(jax.device_put(params, psh),
                         jax.device_put(cache, csh),
                         jax.device_put(tok_new, batch_sharding(rules, tok_new)))
results["decode_parity"] = {
    "dlogit": float(jnp.max(jnp.abs(ref_logits - np.asarray(sh_logits))))}

print("RESULTS_JSON=" + json.dumps(results))
"""


@pytest.mark.slow
def test_sharded_equals_unsharded():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS_JSON=")][-1]
    results = json.loads(line.split("=", 1)[1])
    for arch, r in results.items():
        if arch == "decode_parity":
            assert r["dlogit"] < 0.1, f"decode mismatch: {r}"
            continue
        # bf16 activations + different psum reduction orders: ~1e-2 slack
        assert r["dloss"] < 2e-2, f"{arch} loss mismatch: {r}"
        # DeepSeek's device-limited routing (route_group_limit) only engages
        # on a mesh, so the sharded run deliberately routes a few tokens to
        # different experts than the no-mesh reference — grad norms diverge
        # beyond numerics while loss/params stay in parity.  Measured on this
        # jax: dgrad 0.091 with routing limited, 0.011 with the limit
        # disabled — the bound covers the former with margin, not a blanket
        # relaxation (only deepseek-v2 sets route_group_limit).
        dgrad_bound = 0.12 if r.get("route_limited") else 0.05
        assert r["dgrad"] < dgrad_bound, f"{arch} grad-norm mismatch: {r}"
        assert r["dparam"] < 2e-2, f"{arch} param mismatch: {r}"
