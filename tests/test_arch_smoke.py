"""Per-architecture smoke tests — deliverable (f).

Every assigned arch instantiates its REDUCED config (same family/block
pattern, tiny dims) and runs one forward + one train step on CPU, asserting
output shapes and finiteness.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_SPECS
from repro.models import transformer as tfm
from repro.models.transformer import RunCtx, padded_vocab
from repro.optim import OptimizerConfig
from repro.runtime.steps import StepConfig, init_train_state, make_train_step

ARCH_IDS = sorted(ARCH_SPECS)


def _batch_for(cfg, B=2, S=16):
    key = jax.random.PRNGKey(7)
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    batch = {"inputs": toks, "targets": toks}
    if cfg.vision_tokens:
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_shapes_and_finiteness(arch_id):
    cfg = ARCH_SPECS[arch_id].smoke
    params, axes = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    logits, aux = tfm.forward(params, batch["inputs"], cfg, RunCtx(),
                              extra_embeds=batch.get("image_embeds"))
    B, S = batch["inputs"].shape[:2]
    S_total = S + (cfg.vision_tokens if cfg.vision_tokens else 0)
    Vp = padded_vocab(cfg)
    if cfg.n_codebooks:
        assert logits.shape == (B, S_total, cfg.n_codebooks, Vp)
    else:
        assert logits.shape == (B, S_total, Vp)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)[..., :cfg.vocab_size]))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step_reduces_loss_direction(arch_id):
    """One optimizer step runs, loss is finite, grads flow to every leaf."""
    cfg = ARCH_SPECS[arch_id].smoke
    step_cfg = StepConfig(n_micro=1, remat="none",
                          optimizer=OptimizerConfig(learning_rate=1e-3,
                                                    warmup_steps=1,
                                                    total_steps=10))
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg, step_cfg)
    step = jax.jit(make_train_step(cfg, step_cfg))
    batch = _batch_for(cfg)
    state2, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0.0
    # params actually changed
    changed = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                           state["params"], state2["params"])
    assert max(jax.tree.leaves(changed)) > 0.0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_microbatched_grads_match_full_batch(arch_id):
    """Grad accumulation is exact: n_micro=2 step == n_micro=1 step."""
    cfg = ARCH_SPECS[arch_id].smoke
    opt = OptimizerConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
    batch = _batch_for(cfg, B=4, S=8)
    outs = []
    for n_micro in (1, 2):
        step_cfg = StepConfig(n_micro=n_micro, remat="none", optimizer=opt)
        state, _ = init_train_state(jax.random.PRNGKey(0), cfg, step_cfg)
        _, m = jax.jit(make_train_step(cfg, step_cfg))(state, batch)
        outs.append(m)
    np.testing.assert_allclose(float(outs[0]["loss"]), float(outs[1]["loss"]),
                               rtol=2e-4, atol=2e-4)
    # MoE capacity truncation order can differ per microbatch; allow slack
    np.testing.assert_allclose(float(outs[0]["grad_norm"]),
                               float(outs[1]["grad_norm"]), rtol=0.05)


DECODE_ARCHS = ARCH_IDS   # every assigned arch is decoder-style


@pytest.mark.parametrize("arch_id", DECODE_ARCHS)
def test_smoke_prefill_decode_matches_forward(arch_id):
    cfg = ARCH_SPECS[arch_id].smoke
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg, B=2, S=12)
    toks = batch["inputs"]
    extra = batch.get("image_embeds")
    ctx = RunCtx()
    full, _ = tfm.forward(params, toks, cfg, ctx, extra_embeds=extra)
    off = extra.shape[1] if extra is not None else 0

    T0 = 8
    lp, cache = tfm.prefill(params, toks[:, :T0], cfg, ctx, max_len=24,
                            extra_embeds=extra)
    np.testing.assert_allclose(np.asarray(lp[:, -1], np.float32),
                               np.asarray(full[:, off + T0 - 1], np.float32),
                               atol=3e-2, rtol=3e-2)
    for t in range(T0, toks.shape[1]):
        ld, cache = tfm.decode_step(params, cache, toks[:, t:t + 1], cfg, ctx)
        np.testing.assert_allclose(
            np.asarray(ld[:, 0], np.float32),
            np.asarray(full[:, off + t], np.float32), atol=5e-2, rtol=5e-2)


def test_param_counts_match_published_sizes():
    """The configs ARE the published architectures (within naming slack)."""
    expected_billions = {
        "smollm-135m": (0.13, 0.15),
        "h2o-danube-3-4b": (3.5, 4.2),
        "stablelm-1.6b": (1.4, 1.8),
        "gemma2-27b": (26.0, 28.5),
        "musicgen-medium": (1.2, 1.6),
        "phi3.5-moe-42b-a6.6b": (40.0, 43.0),
        "deepseek-v2-236b": (230.0, 240.0),
        "llava-next-34b": (33.0, 36.0),
        "mamba2-370m": (0.33, 0.42),
        "zamba2-1.2b": (0.9, 1.4),
    }
    for aid, (lo, hi) in expected_billions.items():
        n = ARCH_SPECS[aid].config.param_count() / 1e9
        assert lo <= n <= hi, f"{aid}: {n:.2f}B outside [{lo}, {hi}]"
    # MoE active params
    assert 6.0 <= ARCH_SPECS["phi3.5-moe-42b-a6.6b"].config.active_param_count() / 1e9 <= 7.2
    assert 20.0 <= ARCH_SPECS["deepseek-v2-236b"].config.active_param_count() / 1e9 <= 22.5
