"""The event-driven control plane: bus semantics, online profiler, cluster
coordinator, drift -> re-profile paths, and powershift edge cases."""
import numpy as np
import pytest

from repro.control import (CapApplied, DriftDetected, Event, EventBus,
                           FitUpdated, NodeDerated, PolicyUpdated,
                           PowerSampled, StepDone)
from repro.control.coordinator import ClusterCoordinator
from repro.control.online import OnlineCapProfiler
from repro.core import (BALANCED, CapProfiler, ClusterNode, FrostService,
                        PowerCappedDevice, QoSPolicy, RTX_3080, TPU_V5E,
                        WorkloadProfile, allocate_power)
from repro.core.profiler import RecordingBackend
from repro.telemetry.meters import DramMeter
from repro.telemetry.sampler import PowerSampler

WL_COMPUTE = WorkloadProfile(name="big", flops_per_step=5e12,
                             hbm_bytes_per_step=2e9, samples_per_step=128)
WL_MEMORY = WorkloadProfile(name="decode", flops_per_step=5e10,
                            hbm_bytes_per_step=1.5e10, samples_per_step=128)


def drive(bus, backend, device, wl, n_steps, node_id="node-0", start=0):
    """Simulated node: run n steps under whatever cap is currently enforced,
    streaming StepDone events (the launchers' emit loop, minus the model)."""
    for i in range(start, start + n_steps):
        est = device.estimate(wl, backend.current_cap())
        bus.publish(StepDone(node_id=node_id, step=i,
                             duration_s=est.step_time_s,
                             samples=wl.samples_per_step,
                             energy_j=est.energy_j))


# --------------------------------------------------------------------------
# bus semantics
# --------------------------------------------------------------------------
def test_bus_publish_subscribe_unsubscribe():
    bus = EventBus()
    seen = []
    unsub = bus.subscribe(StepDone, seen.append)
    assert bus.publish(StepDone(node_id="n", step=1, duration_s=0.1)) == 1
    assert bus.publish(PowerSampled(node_id="n", t=0.0, gpu_w=5.0)) == 0
    unsub()
    bus.publish(StepDone(node_id="n", step=2, duration_s=0.1))
    assert [e.step for e in seen] == [1]


def test_bus_isinstance_dispatch_and_history():
    bus = EventBus(history=4)
    everything = []
    bus.subscribe(Event, everything.append)       # base class sees all
    bus.publish(StepDone(node_id="n", step=1, duration_s=0.1))
    bus.publish(PowerSampled(node_id="n", t=0.0))
    assert len(everything) == 2
    assert len(bus.events_of(StepDone)) == 1
    for i in range(10):
        bus.publish(StepDone(node_id="n", step=i, duration_s=0.1))
    assert len(bus.history) == 4                  # ring buffer


def test_bus_handler_errors_are_isolated():
    bus = EventBus()
    seen = []

    def bad(_):
        raise RuntimeError("subscriber exploded")

    bus.subscribe(StepDone, bad)
    bus.subscribe(StepDone, seen.append)
    n = bus.publish(StepDone(node_id="n", step=1, duration_s=0.1))
    assert n == 2 and len(seen) == 1              # pipeline survives
    assert len(bus.drain_errors()) == 1 and not bus.errors


def test_bus_retry_recovers_transient_failure():
    """A handler that fails transiently is retried within the publish; a
    success on any attempt means no error record and no dead letter."""
    bus = EventBus(max_retries=2)
    calls = {"n": 0}
    seen = []

    def flaky(ev):
        calls["n"] += 1
        if calls["n"] < 3:                        # fails twice, then works
            raise RuntimeError("transient")
        seen.append(ev)

    bus.subscribe(StepDone, flaky)
    bus.publish(StepDone(node_id="n", step=1, duration_s=0.1))
    assert len(seen) == 1 and calls["n"] == 3
    assert bus.n_retries == 2
    assert bus.n_dead_lettered == 0 and not bus.errors


def test_bus_dead_letter_and_redeliver():
    """Retry exhaustion dead-letters the event WITH its payload; a
    recovered consumer replays it via redeliver_dead_letters."""
    bus = EventBus(max_retries=1)
    healthy = {"ok": False}
    seen = []

    def consumer(ev):
        if not healthy["ok"]:
            raise RuntimeError("consumer down")
        seen.append(ev)

    bus.subscribe(StepDone, consumer)
    bus.publish(StepDone(node_id="n", step=7, duration_s=0.1))
    assert not seen and bus.n_dead_lettered == 1
    dl = bus.dead_letters[0]
    assert dl.attempts == 2 and dl.event.step == 7
    healthy["ok"] = True
    assert bus.redeliver_dead_letters() == 1
    assert [e.step for e in seen] == [7]
    assert not bus.dead_letters                   # drained on success


def test_bus_redeliver_refailure_re_dead_letters():
    bus = EventBus(max_retries=0)
    bus.subscribe(StepDone, lambda ev: (_ for _ in ()).throw(
        RuntimeError("still down")))
    bus.publish(StepDone(node_id="n", step=1, duration_s=0.1))
    assert bus.redeliver_dead_letters() == 0
    assert len(bus.dead_letters) == 1             # re-dead-lettered, kept


def test_bus_backoff_is_exponential_and_injectable():
    sleeps = []
    bus = EventBus(max_retries=3, backoff_s=0.1, sleep=sleeps.append)
    bus.subscribe(StepDone, lambda ev: (_ for _ in ()).throw(
        RuntimeError("hard down")))
    bus.publish(StepDone(node_id="n", step=1, duration_s=0.1))
    # 4 attempts -> 3 inter-attempt sleeps, doubling each time
    assert sleeps == pytest.approx([0.1, 0.2, 0.4])
    assert bus.n_dead_lettered == 1 and bus.n_retries == 3


def test_power_sampler_publishes_on_bus():
    bus = EventBus()
    sampler = PowerSampler({"dram": DramMeter(4, 16)}, rate_hz=0.1,
                           bus=bus, node_id="host-1")
    sampler.sample_once()
    ev = bus.events_of(PowerSampled)
    assert len(ev) == 1 and ev[0].node_id == "host-1"
    assert ev[0].dram_w == pytest.approx(24.0)    # 4 x 3/8 x 16
    assert sampler.ledger is not None and sampler.n_samples == 1


def test_batch_profiler_publishes_cap_events():
    bus = EventBus()
    dev = PowerCappedDevice(RTX_3080)

    class W:
        def probe(self, cap, duration_s):
            return dev.probe(WL_MEMORY, cap, duration_s)

    decision = CapProfiler(W(), policy=BALANCED, bus=bus).run()
    caps = bus.events_of(CapApplied)
    assert sum(1 for c in caps if c.reason == "probe") == 8
    assert caps[-1].reason == "decision"
    assert caps[-1].cap == pytest.approx(decision.cap)


# --------------------------------------------------------------------------
# online profiler
# --------------------------------------------------------------------------
def test_online_profiler_converges_from_stream():
    bus = EventBus()
    backend = RecordingBackend()
    dev = PowerCappedDevice(TPU_V5E)
    prof = OnlineCapProfiler(bus, backend, policy=BALANCED,
                             steps_per_probe=2, hold_steps=8,
                             min_refresh_interval_s=0.0)
    drive(bus, backend, dev, WL_MEMORY, 40)
    assert prof.decision is not None
    assert prof.mode == "hold"
    # memory-bound => deep cap is near-free; must undercut the uncapped case
    assert prof.decision.cap <= 0.7
    assert 0.3 <= backend.current_cap() <= 1.0
    decisions = [c for c in bus.events_of(CapApplied) if c.reason == "decision"]
    assert decisions, "at least one decision cap command on the bus"
    assert bus.events_of(FitUpdated)


def test_online_profiler_amortises_probes_in_hold():
    bus = EventBus()
    backend = RecordingBackend()
    dev = PowerCappedDevice(TPU_V5E)
    prof = OnlineCapProfiler(bus, backend, policy=BALANCED,
                             steps_per_probe=1, hold_steps=4,
                             min_refresh_interval_s=0.0)
    drive(bus, backend, dev, WL_COMPUTE, 60)
    probes = [c for c in bus.events_of(CapApplied) if c.reason == "probe"]
    # initial sweep (8) plus round-robin refreshes, never a second full sweep
    assert len(probes) > 8
    assert prof.n_refits >= 2                     # refreshes refit incrementally


def test_online_profiler_detects_drift_and_resweeps():
    bus = EventBus()
    backend = RecordingBackend()
    dev = PowerCappedDevice(TPU_V5E)
    prof = OnlineCapProfiler(bus, backend, policy=BALANCED,
                             steps_per_probe=2, hold_steps=8,
                             min_refresh_interval_s=0.0)
    drive(bus, backend, dev, WL_COMPUTE, 40)
    cap_before = prof.decision.cap
    # workload changes character under us: compute-bound -> memory-bound
    drive(bus, backend, dev, WL_MEMORY, 60, start=40)
    drifts = bus.events_of(DriftDetected)
    assert drifts and drifts[0].drift > prof.drift_threshold
    assert prof.decision is not None
    assert prof.decision.cap < cap_before         # deeper cap fits decode


def test_online_profiler_policy_update_retunes_without_resweep():
    bus = EventBus()
    backend = RecordingBackend()
    dev = PowerCappedDevice(TPU_V5E)
    prof = OnlineCapProfiler(bus, backend, policy=QoSPolicy(edp_exponent=1.0),
                             steps_per_probe=2, hold_steps=8,
                             min_refresh_interval_s=0.0)
    drive(bus, backend, dev, WL_COMPUTE, 30)
    cap_lean = prof.decision.cap
    refits_before = prof.n_refits
    bus.publish(PolicyUpdated(node_id="node-0",
                              policy=QoSPolicy(edp_exponent=3.0)))
    # the accumulated buckets are still valid physics: refit, don't resweep
    assert prof.n_refits == refits_before + 1
    assert prof.decision.cap >= cap_lean - 1e-9   # delay-lean => higher cap


def test_online_profiler_without_energy_parks_at_max_cap():
    """No sampler and energy_j=0: the profiler must not throttle the
    pipeline on blind data — it parks at the highest legal cap and waits."""
    bus = EventBus()
    backend = RecordingBackend()
    prof = OnlineCapProfiler(bus, backend, policy=BALANCED,
                             steps_per_probe=1, hold_steps=4,
                             min_refresh_interval_s=0.0)
    for i in range(20):
        bus.publish(StepDone(node_id="node-0", step=i, duration_s=0.01))
    assert prof.mode == "waiting"
    assert backend.current_cap() == pytest.approx(1.0)
    assert prof.n_refits == 0
    # telemetry appears (PowerSampled watts): sweep restarts and converges
    dev = PowerCappedDevice(TPU_V5E)
    bus.publish(PowerSampled(node_id="node-0", t=0.0, gpu_w=150.0))
    drive(bus, backend, dev, WL_COMPUTE, 40, start=20)
    assert prof.mode != "waiting"
    assert prof.decision is not None


def test_online_profiler_drift_check_uses_per_sample_units():
    """A StepDone stream whose time/sample matches the warm-start profile
    must NOT trip drift, whatever the absolute samples count is."""
    dev = PowerCappedDevice(TPU_V5E)

    class W:
        def probe(self, cap, duration_s):
            return dev.probe(WL_COMPUTE, cap, duration_s)

    batch = CapProfiler(W(), policy=BALANCED).run()
    bus = EventBus()
    backend = RecordingBackend()
    prof = OnlineCapProfiler(bus, backend, policy=BALANCED,
                             warm_start=batch, hold_steps=64)
    drive(bus, backend, dev, WL_COMPUTE, 12)
    assert not bus.events_of(DriftDetected)
    assert prof.decision is not None and prof.decision.cap == batch.cap


def test_online_profiler_policy_narrowing_evicts_illegal_cap():
    """Hysteresis must never defend a cap outside a newly-narrowed policy
    window — the enforced cap has to move inside [min_cap, max_cap]."""
    bus = EventBus()
    backend = RecordingBackend()
    dev = PowerCappedDevice(TPU_V5E)
    prof = OnlineCapProfiler(bus, backend, policy=QoSPolicy(edp_exponent=3.0),
                             steps_per_probe=2, hold_steps=8,
                             min_refresh_interval_s=0.0)
    drive(bus, backend, dev, WL_COMPUTE, 30)          # latency-lean: high cap
    bus.publish(PolicyUpdated(node_id="node-0",
                              policy=QoSPolicy(policy_id="narrow",
                                               edp_exponent=3.0,
                                               max_cap=0.80)))
    assert backend.current_cap() <= 0.80 + 1e-9
    drive(bus, backend, dev, WL_COMPUTE, 20, start=30)
    assert backend.current_cap() <= 0.80 + 1e-9       # stays legal


def test_service_reprofile_publishes_profiler_caps_once():
    """A bus-attached service routes its CapProfiler through the bus: probe
    and decision caps appear as CapApplied events, with no duplicates."""
    bus = EventBus()
    cap_log = bus.tap(CapApplied)
    svc = FrostService("n0", probe_seconds=5.0, bus=bus)
    svc.on_new_model("m", _Workload(WL_COMPUTE))
    probes = [c for c in cap_log if c.reason == "probe"]
    decisions = [c for c in cap_log if c.reason == "decision"]
    assert len(probes) == 8
    assert len(decisions) == 1


def test_online_profiler_warm_start_skips_sweep():
    dev = PowerCappedDevice(TPU_V5E)

    class W:
        def probe(self, cap, duration_s):
            return dev.probe(WL_COMPUTE, cap, duration_s)

    batch = CapProfiler(W(), policy=BALANCED).run()
    bus = EventBus()
    backend = RecordingBackend()
    prof = OnlineCapProfiler(bus, backend, policy=BALANCED,
                             warm_start=batch, hold_steps=64)
    assert prof.mode == "hold"
    assert backend.current_cap() == pytest.approx(batch.cap)
    drive(bus, backend, dev, WL_COMPUTE, 10)
    probes = [c for c in bus.events_of(CapApplied) if c.reason == "probe"]
    assert not probes                             # no dedicated probe windows


# --------------------------------------------------------------------------
# FrostService: drift -> re-profile (direct call and via the bus)
# --------------------------------------------------------------------------
class _Workload:
    def __init__(self, wl, dev=None):
        self.dev = dev or PowerCappedDevice(RTX_3080)
        self.wl = wl

    def probe(self, cap, duration_s):
        return self.dev.probe(self.wl, cap, duration_s)


def test_service_drift_triggers_reprofile_direct_call():
    svc = FrostService("n0", probe_seconds=5.0)
    d0 = svc.on_new_model("m", _Workload(WL_COMPUTE))
    # small wobble: no re-profile
    expected = FrostService._interp_time(d0, d0.cap)
    assert svc.on_step_report("m", expected * 1.05) is None
    # big drift: re-profile fires WITHOUT passing the workload again (the
    # service remembers how to probe the model it deployed)
    d1 = svc.on_step_report("m", expected * 2.0)
    assert d1 is not None
    kinds = [e.kind for e in svc.events]
    assert kinds.count("profiled") == 2 and "drift" in kinds


def test_service_drift_reprofile_via_bus_events():
    bus = EventBus()
    svc = FrostService("n0", probe_seconds=5.0, bus=bus)
    d0 = svc.on_new_model("m", _Workload(WL_COMPUTE))
    expected = FrostService._interp_time(d0, d0.cap)
    bus.publish(StepDone(node_id="n0", step=1, duration_s=expected * 2.0,
                         samples=1, model_id="m"))
    assert len(bus.events_of(DriftDetected)) == 1
    kinds = [e.kind for e in svc.events]
    assert kinds.count("profiled") == 2           # bus-driven re-profile
    # other nodes' events are ignored
    bus.publish(StepDone(node_id="other", step=2, duration_s=expected * 9,
                         samples=1, model_id="m"))
    assert kinds.count("profiled") == 2


def test_service_drift_without_reprofile_publishes_only():
    """reprofile_on_drift=False: the service flags drift on the bus but never
    blocks the publish path with a batch re-profile (that's the online
    profiler's job)."""
    bus = EventBus()
    svc = FrostService("n0", probe_seconds=5.0, bus=bus,
                       reprofile_on_drift=False)
    d0 = svc.on_new_model("m", _Workload(WL_COMPUTE))
    expected = FrostService._interp_time(d0, d0.cap)
    bus.publish(StepDone(node_id="n0", step=1, duration_s=expected * 2.0,
                         samples=1, model_id="m"))
    assert len(bus.events_of(DriftDetected)) == 1
    kinds = [e.kind for e in svc.events]
    assert kinds.count("profiled") == 1           # no blocking re-profile


def test_service_policy_via_bus_invalidates_decisions():
    bus = EventBus()
    svc = FrostService("n0", probe_seconds=5.0, bus=bus)
    svc.on_new_model("m", _Workload(WL_COMPUTE))
    assert svc.decision_for("m") is not None
    bus.publish(PolicyUpdated(node_id="n0",
                              policy=QoSPolicy(policy_id="new-ed1p",
                                               edp_exponent=1.0)))
    assert svc.policy.policy_id == "new-ed1p"
    assert svc.decision_for("m") is None          # cached caps invalidated


# --------------------------------------------------------------------------
# cluster coordinator
# --------------------------------------------------------------------------
def test_coordinator_infers_derate_and_shifts_power():
    bus = EventBus()
    budget = 0.9 * 4 * TPU_V5E.tdp_w
    coord = ClusterCoordinator(bus, global_budget_w=budget,
                               rebalance_every=8)
    true_dev = {}
    backends = {}
    for i in range(4):
        nid = f"n{i}"
        derate = 0.75 if i == 2 else 1.0
        true_dev[nid] = PowerCappedDevice(TPU_V5E, derate=derate)
        node = ClusterNode(nid, PowerCappedDevice(TPU_V5E), WL_COMPUTE)
        backends[nid] = coord.register_node(node)

    for step in range(2):
        for nid, dev in true_dev.items():
            est = dev.estimate(WL_COMPUTE, backends[nid].current_cap())
            bus.publish(PowerSampled(node_id=nid, t=float(step),
                                     gpu_w=est.power_w))
            bus.publish(StepDone(node_id=nid, step=step,
                                 duration_s=est.step_time_s,
                                 samples=WL_COMPUTE.samples_per_step,
                                 energy_j=est.energy_j))

    assert coord.plans, "rebalance fired after rebalance_every step events"
    assert coord.derates()["n2"] < 0.9 < coord.derates()["n0"]
    caps = coord.current_caps()
    assert caps["n2"] > caps["n0"]                # straggler gets more watts
    plan = coord.plans[-1]
    assert plan.total_power_w <= budget * 1.001
    assert bus.events_of(CapApplied)              # commands visible on the bus
    # budget audit: measured watts (from PowerSampled EWMAs) were recorded
    audit = coord.audit[-1]
    assert audit["window_measured_w"] is not None
    assert audit["window_measured_w"] > 0
    assert audit["budget_w"] == pytest.approx(budget)


def test_coordinator_ignores_unknown_nodes():
    bus = EventBus()
    coord = ClusterCoordinator(bus, global_budget_w=1000.0, rebalance_every=1)
    coord.register_node(ClusterNode("n0", PowerCappedDevice(TPU_V5E),
                                    WL_COMPUTE))
    bus.publish(StepDone(node_id="ghost", step=0, duration_s=0.1))
    assert not coord.plans                        # ghost didn't trip rebalance


def test_coordinator_adopts_published_derate():
    """A NodeDerated published by a serving supervisor lands in the
    coordinator's derate estimate immediately — fresher than waiting a
    whole rebalance window of StepDone latencies."""
    bus = EventBus()
    coord = ClusterCoordinator(bus, global_budget_w=1000.0,
                               rebalance_every=1000)
    coord.register_node(ClusterNode("serve-0", PowerCappedDevice(TPU_V5E),
                                    WL_MEMORY))
    bus.publish(NodeDerated(node_id="serve-0", derate=0.7,
                            source="serving-supervisor"))
    assert coord.derates()["serve-0"] == pytest.approx(0.7)
    bus.publish(NodeDerated(node_id="ghost", derate=0.5))   # unknown: ignored
    assert "ghost" not in coord.derates()


# --------------------------------------------------------------------------
# allocate_power edge cases
# --------------------------------------------------------------------------
def test_allocate_power_infeasible_budget_is_best_effort():
    nodes = [ClusterNode(f"n{i}", PowerCappedDevice(TPU_V5E), WL_COMPUTE)
             for i in range(3)]
    floor_w = sum(TPU_V5E.min_cap * TPU_V5E.tdp_w for _ in nodes)
    plan = allocate_power(nodes, floor_w * 0.5)   # below the physical floor
    assert not plan.feasible
    for a in plan.allocations:                    # best effort: min caps
        assert a.cap == pytest.approx(TPU_V5E.min_cap)


def test_allocate_power_single_node_cluster():
    node = ClusterNode("solo", PowerCappedDevice(TPU_V5E), WL_COMPUTE)
    generous = allocate_power([node], 2 * TPU_V5E.tdp_w)
    assert generous.feasible
    # cheapest cap achieving the uncapped step time (clock saturates <1.0)
    t_uncapped = node.step_time(1.0)
    assert generous.step_time_s == pytest.approx(t_uncapped, rel=1e-3)
    tight = allocate_power([node], 0.5 * TPU_V5E.tdp_w)
    assert tight.allocations[0].cap <= 0.5 + 1e-6
    assert tight.total_power_w <= 0.5 * TPU_V5E.tdp_w * 1.001


def test_allocate_power_heterogeneous_tdps():
    # a 215 W TPU next to a 320 W GPU: caps are fractions of DIFFERENT TDPs
    nodes = [ClusterNode("tpu", PowerCappedDevice(TPU_V5E), WL_COMPUTE),
             ClusterNode("gpu", PowerCappedDevice(RTX_3080), WL_COMPUTE)]
    budget = 0.8 * (TPU_V5E.tdp_w + RTX_3080.tdp_w)
    plan = allocate_power(nodes, budget)
    assert plan.feasible
    assert plan.total_power_w <= budget * 1.001
    by_id = {a.node_id: a for a in plan.allocations}
    assert by_id["tpu"].power_w <= TPU_V5E.tdp_w + 1e-6
    assert by_id["gpu"].power_w <= RTX_3080.tdp_w + 1e-6
    # the slower device is the straggler: it must not be starved below the
    # faster one's cap fraction of its OWN tdp
    assert by_id["gpu"].cap >= by_id["tpu"].cap - 1e-6


def test_allocate_power_empty_cluster_raises():
    with pytest.raises(ValueError):
        allocate_power([], 100.0)
