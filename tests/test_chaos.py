"""Chaos-injection + crash-recovery tests: injector semantics, paged-KV
corruption audit/quarantine, engine snapshot/restore exactness, graceful
degradation under power emergencies, and the lossy-telemetry bus shim."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.control import EventBus, StepDone
from repro.models import transformer as tfm
from repro.runtime.chaos import (ChaosBus, FaultEvent, FaultInjector,
                                 corrupt_paged_kv)
from repro.serving import (EngineConfig, EngineCrash, PagedKVCache,
                           ServeEngine, poisson_trace)


@pytest.fixture(scope="module")
def tiny():
    """Shrunk below the smoke config: these tests exercise host-side
    recovery mechanics, not model compute."""
    spec = get_arch("smollm-135m")
    cfg = dataclasses.replace(spec.smoke, d_model=64, d_ff=128, head_dim=16)
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


ECFG = EngineConfig(n_slots=2, page_size=4, max_len=48, decode_chunk=4)


def _trace(cfg, n=5, seed=7):
    return poisson_trace(n, rate_per_step=0.3, seed=seed,
                         vocab_size=cfg.vocab_size, prompt_len=(3, 13),
                         max_new_tokens=(4, 10))


def _streams(rep):
    return {r.rid: list(np.asarray(r.tokens).ravel()) for r in rep.results}


def _run_with_recovery(cfg, params, trace, injector, snap, *, ecfg=ECFG,
                       snapshot_every=2, **kwargs):
    eng = ServeEngine(cfg, ecfg, params, injector=injector,
                      snapshot_dir=str(snap), snapshot_every=snapshot_every,
                      **kwargs)
    restarts = 0
    while True:
        try:
            return eng, (eng.resume() if restarts else eng.run(trace))
        except EngineCrash:
            restarts += 1
            assert restarts <= 3, "crash replayed after restore"
            eng = ServeEngine.restore(cfg, ecfg, params, str(snap),
                                      injector=injector,
                                      snapshot_every=snapshot_every,
                                      **kwargs)


# --------------------------------------------------------------------------
# injector semantics
# --------------------------------------------------------------------------
def test_fault_event_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(kind="gremlin", step=3)


def test_injector_fires_once_in_step_order():
    inj = FaultInjector()
    inj.schedule("derate", 10, duration=4, arg=0.8)
    inj.schedule("slot_crash", 5, arg=1)
    assert inj.pending() == 2
    assert [e.kind for e in inj.poll(4)] == []
    due = inj.poll(12)                        # both due; fires in step order
    assert [(e.kind, e.step) for e in due] == [("slot_crash", 5),
                                               ("derate", 10)]
    assert inj.poll(20) == []                 # one-shot: never re-fires
    assert inj.pending() == 0 and inj.n_injected == 2
    assert [e.kind for e in inj.log] == ["slot_crash", "derate"]


def test_injector_from_spec_roundtrip():
    inj = FaultInjector.from_spec(
        "engine_crash@40, emergency_cap@10:8:0.5,bus_drop@3")
    assert [(e.kind, e.step, e.duration, e.arg) for e in inj.events] == [
        ("bus_drop", 3, 0, 0.0), ("emergency_cap", 10, 8, 0.5),
        ("engine_crash", 40, 0, 0.0)]
    with pytest.raises(ValueError, match="expected kind@step"):
        FaultInjector.from_spec("engine_crash")


# --------------------------------------------------------------------------
# lossy telemetry transport
# --------------------------------------------------------------------------
def test_chaos_bus_drop_delay_flush():
    bus = EventBus()
    seen = bus.tap(StepDone)
    cbus = ChaosBus(bus)

    def ev(step):
        return StepDone(node_id="n", step=step, duration_s=0.1)

    cbus.drop_next(1)
    cbus.publish(ev(0))                       # vanishes
    cbus.delay_next(2)
    cbus.publish(ev(1))
    cbus.publish(ev(2))                       # both held
    assert [e.step for e in seen] == []
    cbus.publish(ev(3))                       # clean publish flushes first
    assert [e.step for e in seen] == [1, 2, 3]
    cbus.delay_next(1)
    cbus.publish(ev(4))
    assert cbus.flush() == 1                  # explicit drain
    assert [e.step for e in seen] == [1, 2, 3, 4]
    assert cbus.n_dropped == 1 and cbus.n_delayed == 3
    assert cbus.subscribers(StepDone) == 1    # proxies to the inner bus


# --------------------------------------------------------------------------
# paged-KV corruption audit
# --------------------------------------------------------------------------
def _loaded_kv(cfg, seed=0):
    rng = np.random.default_rng(seed)
    kv = PagedKVCache(cfg, n_slots=2, page_size=4, max_len=32, n_pages=14)
    for slot in range(2):
        tokens = rng.integers(0, 3, size=9 + slot).astype(np.int32)
        kv.admit_with_prefix(slot, tokens, len(tokens) + 4)
        kv.register_prefix(slot, tokens)
    kv.release(1)                             # trie keeps pages live + free
    return kv


def test_verify_invariants_clean_pool(tiny):
    cfg, _ = tiny
    assert _loaded_kv(cfg).verify_invariants() == []


def test_corruption_detected_then_repaired_and_quarantined(tiny):
    """Every corruption kind the injector can produce is caught by the
    audit, and repair leaves a pool that passes a clean re-audit with the
    implicated pages quarantined out of circulation."""
    cfg, _ = tiny
    kinds_seen = set()
    for seed in range(12):
        kv = _loaded_kv(cfg, seed=seed)
        desc = corrupt_paged_kv(kv, np.random.default_rng(seed))
        assert desc is not None
        kinds_seen.add(desc.split(":")[0])
        assert kv.verify_invariants() != []   # detected
        kv.verify_invariants(repair=True)
        assert kv.verify_invariants() == []   # repaired
        assert not (set(kv.free) & kv.quarantined)
    assert kinds_seen == {"refcount", "free_dup", "stale_trie"}


def test_quarantined_pages_stay_out_of_circulation(tiny):
    cfg, _ = tiny
    kv = _loaded_kv(cfg)
    # the slot's last page covers a partial-page tail the trie never
    # indexed — the slot is its only holder, so release drops it to zero
    victim = kv.allocated[0][-1]
    kv.quarantined.add(victim)
    kv.release(0)
    assert kv.refcount[victim] == 0
    assert victim not in kv.free              # never handed out again


# --------------------------------------------------------------------------
# crash -> restore exactness
# --------------------------------------------------------------------------
def test_engine_crash_restore_streams_exact(tiny, tmp_path):
    """Mid-run engine crash, restore from the last snapshot, resume: every
    greedy stream bit-identical to the fault-free run, zero tokens lost."""
    cfg, params = tiny
    trace = _trace(cfg)
    base = _streams(ServeEngine(cfg, ECFG, params).run(trace))
    inj = FaultInjector()
    inj.schedule("engine_crash", 14)
    eng, rep = _run_with_recovery(cfg, params, trace, inj, tmp_path)
    assert rep.n_restores == 1 and rep.n_faults_injected >= 1
    assert _streams(rep) == base
    assert eng.kv.verify_invariants() == []


def test_slot_crash_and_corruption_invisible_in_output(tiny, tmp_path):
    cfg, params = tiny
    trace = _trace(cfg, seed=11)
    base = _streams(ServeEngine(cfg, ECFG, params).run(trace))
    inj = FaultInjector(seed=3)
    inj.schedule("slot_crash", 6, arg=0)
    inj.schedule("slot_crash", 10, arg=1)
    inj.schedule("page_corrupt", 12)
    eng, rep = _run_with_recovery(cfg, params, trace, inj, tmp_path)
    assert _streams(rep) == base
    assert rep.n_faults_injected == 3
    assert eng.kv.verify_invariants() == []


def test_emergency_cap_degrades_then_recovers(tiny, tmp_path):
    """An emergency-cap window pauses admission and halves the decode
    chunk; service degrades instead of stopping, the window expires, and
    the output is untouched."""
    cfg, params = tiny
    # busy trace: slots must be occupied when the window hits, so degraded
    # chunks (not just idle clock-jumps) are exercised
    trace = poisson_trace(8, rate_per_step=0.8, seed=13,
                          vocab_size=cfg.vocab_size, prompt_len=(3, 10),
                          max_new_tokens=(8, 12))
    base = _streams(ServeEngine(cfg, ECFG, params).run(trace))
    inj = FaultInjector()
    inj.schedule("emergency_cap", 8, duration=10, arg=0.5)
    chunks = []
    eng, rep = _run_with_recovery(cfg, params, trace, inj, tmp_path,
                                  on_chunk=lambda s: chunks.append(s) and None)
    assert _streams(rep) == base
    assert rep.degraded_steps > 0
    degraded = [c for c in chunks if c.degrade_level >= 2 and c.n_active]
    assert degraded and all(         # chunk halved: computed = active * c/2
        c.tokens_computed == c.n_active * (ECFG.decode_chunk // 2)
        for c in degraded)
    assert chunks[-1].degrade_level == 0      # recovered: full service
    assert eng.degrade_level == 0


def test_speculative_crash_restore_exact(tiny, tmp_path):
    """Crash + emergency cap on the speculative engine: the cap window
    drops spec-K (verify compute shed first), the crash restores, and the
    streams still match the plain fault-free engine exactly."""
    cfg, params = tiny
    ecfg = dataclasses.replace(ECFG, spec_k=2, drafter="ngram")
    trace = _trace(cfg, seed=17)
    base = _streams(ServeEngine(cfg, ECFG, params).run(trace))
    inj = FaultInjector()
    inj.schedule("emergency_cap", 6, duration=8, arg=0.5)
    inj.schedule("engine_crash", 16)
    _, rep = _run_with_recovery(cfg, params, trace, inj, tmp_path,
                                ecfg=ecfg)
    assert _streams(rep) == base
    assert rep.n_restores == 1 and rep.degraded_steps > 0


def test_stall_suppresses_heartbeats(tiny):
    cfg, params = tiny
    trace = _trace(cfg, seed=19)
    base_beats = []
    ServeEngine(cfg, ECFG, params,
                on_heartbeat=lambda s, w: base_beats.append(s)).run(trace)
    beats = []
    inj = FaultInjector()
    inj.schedule("stall", 8, duration=12)
    rep = ServeEngine(cfg, ECFG, params, injector=inj,
                      on_heartbeat=lambda s, w: beats.append(s)).run(trace)
    assert len(base_beats) == rep.n_chunks    # healthy: one beat per chunk
    assert beats and len(beats) < len(base_beats)   # stall went silent


def test_bus_faults_forward_to_on_fault(tiny):
    cfg, params = tiny
    inj = FaultInjector()
    inj.schedule("bus_drop", 4, duration=2)
    inj.schedule("bus_delay", 8, duration=1)
    forwarded = []
    eng = ServeEngine(cfg, ECFG, params, injector=inj,
                      on_fault=forwarded.append)
    eng.run(_trace(cfg, seed=23))
    assert [e.kind for e in forwarded] == ["bus_drop", "bus_delay"]


# --------------------------------------------------------------------------
# snapshot round-trip
# --------------------------------------------------------------------------
def test_kv_state_dict_roundtrip(tiny):
    cfg, _ = tiny
    kv = _loaded_kv(cfg, seed=5)
    state = kv.state_dict()
    kv2 = PagedKVCache(cfg, n_slots=2, page_size=4, max_len=32, n_pages=14)
    kv2.load_state(state)
    assert kv2.verify_invariants() == []
    np.testing.assert_array_equal(kv2.tables, kv.tables)
    np.testing.assert_array_equal(kv2.refcount, kv.refcount)
    assert list(kv2.free) == list(kv.free)
    assert kv2.allocated == kv.allocated
    assert kv2.state_dict() == state          # fixed point


def test_kv_load_state_rejects_config_mismatch(tiny):
    cfg, _ = tiny
    state = _loaded_kv(cfg).state_dict()
    other = PagedKVCache(cfg, n_slots=2, page_size=8, max_len=32, n_pages=14)
    with pytest.raises(ValueError):
        other.load_state(state)


def test_restored_engine_reuses_prefix_pages(tiny, tmp_path):
    """The crash fold registers each dead slot's written tokens in the
    trie before release — the requeued request's re-prefill restores from
    cache instead of recomputing."""
    cfg, params = tiny
    trace = _trace(cfg, seed=29)
    inj = FaultInjector()
    inj.schedule("engine_crash", 14)
    _, rep = _run_with_recovery(cfg, params, trace, inj, tmp_path)
    assert rep.requeued_requests >= 1
    assert rep.prefill_tokens_saved > 0
