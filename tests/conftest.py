"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (single) device; only launch/dryrun.py forces 512 devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
