"""FROST core: energy accounting (Eqs 1-5), ED^mP, the F(x) fit (Eqs 6-7),
the downhill simplex, the cap profiler, policies, and power shifting."""
import numpy as np
import pytest

from repro.core import (BALANCED, ENERGY_LEAN, LATENCY_LEAN, CapProfiler,
                        ClusterNode, EnergyLedger, PowerCappedDevice,
                        PowerSample, QoSPolicy, RTX_3080, RTX_3090, TPU_V5E,
                        WorkloadProfile, allocate_power, detect_stragglers,
                        dram_power_estimate, edp, f_curve, fit_cost_curve,
                        integrate_power, minimize_fit, nelder_mead)
from repro.core.edp import CapMeasurement, normalized_costs
from repro.core.simplex import minimize_scalar_on_interval


# --------------------------------------------------------------------------
# energy accounting
# --------------------------------------------------------------------------
def test_dram_rule_of_thumb():
    # paper setup no.1: 4 x 16 GB DIMMs -> 4 * 3/8 * 16 = 24 W
    assert dram_power_estimate(4, 16) == pytest.approx(24.0)
    # setup no.2: 4 x 32 GB -> 48 W
    assert dram_power_estimate(4, 32) == pytest.approx(48.0)


def test_integrate_power_trapezoid():
    samples = [PowerSample(t=float(t), cpu_w=100.0) for t in range(11)]
    assert integrate_power(samples) == pytest.approx(1000.0)


def test_energy_ledger_idle_subtraction():
    ledger = EnergyLedger(
        idle_trace=[PowerSample(t=float(t), cpu_w=50.0) for t in range(5)])
    ledger.extend([PowerSample(t=float(t), cpu_w=150.0) for t in range(11)])
    rep = ledger.report()
    assert rep.gross_j == pytest.approx(1500.0)
    assert rep.idle_j == pytest.approx(500.0)     # 50 W x 10 s
    assert rep.net_j == pytest.approx(1000.0)
    assert rep.mean_power_w == pytest.approx(150.0)


def test_profile_energy_enters_report():
    ledger = EnergyLedger()
    ledger.add_profile_energy(800.0)              # Eq 4 leading term
    ledger.extend([PowerSample(t=0.0, gpu_w=100.0),
                   PowerSample(t=1.0, gpu_w=100.0)])
    assert ledger.report().net_j == pytest.approx(900.0)


# --------------------------------------------------------------------------
# ED^mP
# --------------------------------------------------------------------------
def test_edp_exponent_semantics():
    assert edp(10, 2, 1) == 20
    assert edp(10, 2, 2) == 40
    assert edp(10, 2, 3) == 80
    with pytest.raises(ValueError):
        edp(-1, 1)


def test_higher_exponent_prefers_faster_configs():
    fast = CapMeasurement(cap=1.0, energy_j=100.0, delay_s=1.0, samples=10)
    slow = CapMeasurement(cap=0.5, energy_j=40.0, delay_s=2.0, samples=10)
    # energy-lean: slow/capped wins; latency-lean: fast wins
    assert slow.cost(1) < fast.cost(1)
    assert slow.cost(3) > fast.cost(3)


# --------------------------------------------------------------------------
# simplex + fit
# --------------------------------------------------------------------------
def test_nelder_mead_rosenbrock():
    f = lambda x: (1 - x[0]) ** 2 + 100 * (x[1] - x[0] ** 2) ** 2
    res = nelder_mead(f, [-1.2, 1.0], max_iter=5000)
    np.testing.assert_allclose(res.x, [1.0, 1.0], atol=1e-4)


def test_minimize_scalar_on_interval():
    x, fx = minimize_scalar_on_interval(lambda x: (x - 0.42) ** 2, 0.3, 1.0)
    assert x == pytest.approx(0.42, abs=1e-5)


def test_fit_recovers_convex_cost_curve():
    caps = np.arange(0.3, 1.01, 0.1)
    true = 0.4 * np.exp(-6 * (caps - 0.3)) + 0.8 / (1 + np.exp(-8 * (caps - 0.7))) + 0.6
    fit = fit_cost_curve(caps, true)
    assert fit.accepted, f"rel_rmse={fit.rel_rmse}"
    x_opt, _ = minimize_fit(fit)
    brute = caps[np.argmin(true)]
    dense = np.linspace(0.3, 1.0, 1000)
    brute_dense = dense[np.argmin(fit(dense))]
    assert abs(x_opt - brute_dense) < 0.02
    assert abs(x_opt - brute) <= 0.15


def test_fit_rejects_garbage_and_falls_back():
    rng = np.random.default_rng(0)
    caps = np.arange(0.3, 1.01, 0.1)
    y = rng.uniform(0.0, 5.0, size=caps.size)      # unfittable noise
    fit = fit_cost_curve(caps, y)
    x_opt, v = minimize_fit(fit)
    if not fit.accepted:
        # falls back to the best *measured* probe — never extrapolates
        assert x_opt == pytest.approx(caps[np.argmin(y)])


# --------------------------------------------------------------------------
# the analytic device + profiler (paper phenomenology must EMERGE)
# --------------------------------------------------------------------------
def _compute_bound_wl():
    return WorkloadProfile(name="big", flops_per_step=5e12,
                           hbm_bytes_per_step=2e9, samples_per_step=128)


def _memory_bound_wl():
    return WorkloadProfile(name="decode", flops_per_step=5e10,
                           hbm_bytes_per_step=1.5e10, samples_per_step=128)


def test_capping_stretches_compute_bound_steps():
    dev = PowerCappedDevice(TPU_V5E)
    wl = _compute_bound_wl()
    t100 = dev.estimate(wl, 1.0).step_time_s
    t40 = dev.estimate(wl, 0.4).step_time_s
    assert t40 > 1.15 * t100          # compute-bound: deep caps hurt


def test_capping_nearly_free_when_memory_bound():
    dev = PowerCappedDevice(TPU_V5E)
    wl = _memory_bound_wl()
    t100 = dev.estimate(wl, 1.0).step_time_s
    t40 = dev.estimate(wl, 0.4).step_time_s
    assert t40 < 1.10 * t100          # paper Sec IV-C observation
    e100 = dev.estimate(wl, 1.0).energy_j
    e40 = dev.estimate(wl, 0.4).energy_j
    assert e40 < e100                 # and saves energy


def test_profiler_selects_deeper_cap_for_memory_bound():
    class W:
        def __init__(self, wl):
            self.dev = PowerCappedDevice(RTX_3080)
            self.wl = wl

        def probe(self, cap, duration_s):
            return self.dev.probe(self.wl, cap, duration_s)

    d_mem = CapProfiler(W(_memory_bound_wl()), policy=BALANCED).run()
    d_cmp = CapProfiler(W(_compute_bound_wl()), policy=LATENCY_LEAN).run()
    assert d_mem.cap <= d_cmp.cap
    assert 0.3 <= d_mem.cap <= 1.0
    assert d_mem.predicted_energy_saving > 0.0


def test_profiler_respects_policy_window_and_delay_bound():
    class W:
        dev = PowerCappedDevice(RTX_3090)

        def probe(self, cap, duration_s):
            return self.dev.probe(_compute_bound_wl(), cap, duration_s)

    pol = QoSPolicy(policy_id="tight", edp_exponent=1.0,
                    max_delay_increase=0.02)
    d = CapProfiler(W(), policy=pol).run()
    assert d.predicted_delay_increase <= 0.02 + 1e-6


def test_edp_exponent_monotone_in_cap():
    """Paper Fig 5: more delay weight -> higher optimal cap."""
    class W:
        dev = PowerCappedDevice(RTX_3080)

        def probe(self, cap, duration_s):
            return self.dev.probe(_compute_bound_wl(), cap, duration_s)

    caps = [CapProfiler(W(), policy=QoSPolicy(edp_exponent=m)).run().cap
            for m in (1.0, 2.0, 3.0)]
    assert caps[0] <= caps[1] <= caps[2] + 1e-9


# --------------------------------------------------------------------------
# power shifting / stragglers
# --------------------------------------------------------------------------
def test_detect_stragglers():
    out = detect_stragglers([1.0, 1.02, 1.5, 0.98], threshold=1.15)
    assert out == [2]


def test_power_shift_equalises_step_time():
    wl = _compute_bound_wl()
    healthy = ClusterNode("n0", PowerCappedDevice(TPU_V5E), wl)
    derated = ClusterNode("n1", PowerCappedDevice(TPU_V5E, derate=0.8), wl)
    plan = allocate_power([healthy, derated], 2 * 0.9 * TPU_V5E.tdp_w)
    assert plan.feasible
    caps = {a.node_id: a.cap for a in plan.allocations}
    # the derated node gets MORE power budget than the healthy one
    assert caps["n1"] >= caps["n0"]
    times = [a.step_time_s for a in plan.allocations]
    assert max(times) / min(times) < 1.2
    total = sum(a.power_w for a in plan.allocations)
    assert total <= plan.global_budget_w * 1.001
