"""Two-tier KV hierarchy tests: fused-dequant kernel parity, host-tier
invariants under random traffic, engine stream equivalence, and
crash-restore with demoted host pages.

The contract: int8 pages with per-row fp32 scales are bit-stable (rows
quantize once, at write time) and the dequant fused into every split-KV
sweep family matches the quantized ref oracle — so turning the hierarchy
on must not move a single greedy token, and a crash must not lose a page
parked in the host tier.
"""
import dataclasses
import functools
import tempfile
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.kernels import ops, ref

C = 80                 # ring capacity: 5 blocks of 16
BLOCK_K = 16
PS, NB = 16, 5         # paged: 5 pages of 16
D = DV = 16
Q = 4                  # verify block (K+1)
TOL = 5e-6

_HEADS = [(4, 2), (4, 1)]                # (Hq, Hkv): GQA and MQA
_POS = {"wrap": C + 15, "partial": 10}   # wrapped ring / mostly-empty cache
_BACKENDS = ["jnp", "pallas_interpret"]


def _arrays(B, Hq, Hkv, *, seed=0):
    """Random q/candidates plus int8-quantized ring caches and page pools
    with their per-row fp32 scale arrays."""
    rng = np.random.default_rng(seed)
    r = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    a = {
        "q1": r(B, 1, Hq, D), "qv": r(B, Q, Hq, D),
        "kn": r(B, Q, Hkv, D), "vn": r(B, Q, Hkv, DV),
        "bt": jnp.asarray(rng.permutation(16)[:B * NB].reshape(B, NB),
                          jnp.int32),
        "head": r(Hq * DV, 64),
    }
    a["k"], a["ks"] = quant.quantize_int8_rows(r(B, C, Hkv, D))
    a["v"], a["vs"] = quant.quantize_int8_rows(r(B, C, Hkv, DV))
    a["kp"], a["kps"] = quant.quantize_int8_rows(r(16, PS, Hkv, D))
    a["vp"], a["vps"] = quant.quantize_int8_rows(r(16, PS, Hkv, DV))
    return a


def _argmax(out, head):
    return jnp.argmax(out.reshape(out.shape[0], -1, out.shape[2] * out.shape[3])
                      .sum(axis=1) @ head, axis=-1)


def _policy(backend, n_splits):
    return ops.KernelPolicy(decode=backend, kv_splits=n_splits,
                            decode_k_chunk=BLOCK_K)


# --------------------------------------------------------------------------
# quantizer contract
# --------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_quantize_rows_error_bounded_by_half_step(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((3, 5, 2, 8))
                    * 10.0 ** rng.integers(-3, 3), jnp.float32)
    q, s = quant.quantize_int8_rows(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert s.shape == x.shape[:-1] + (1,)
    err = jnp.abs(quant.dequantize_int8_rows(q, s) - x)
    # symmetric absmax + round-to-nearest: per-row error <= scale / 2
    assert float(jnp.max(err - 0.5 * s)) <= 1e-6


# --------------------------------------------------------------------------
# fused-dequant parity: all four sweep families vs the quantized oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("Hq,Hkv", _HEADS)
@pytest.mark.parametrize("backend", _BACKENDS)
@pytest.mark.parametrize("n_splits", [1, 2])
def test_ring_decode_int8_matches_quantized_oracle(Hq, Hkv, backend,
                                                   n_splits):
    a = _arrays(2, Hq, Hkv, seed=Hq * 10 + n_splits)
    for pos_v in _POS.values():
        pos = jnp.int32(pos_v)
        k_pos = ops.ring_positions(pos, C)
        oracle = ref.decode_attention_ref(a["q1"], a["k"], a["v"], k_pos,
                                          pos, k_scale=a["ks"],
                                          v_scale=a["vs"])
        got = ops.decode_attention(a["q1"], a["k"], a["v"], pos,
                                   k_scale=a["ks"], v_scale=a["vs"],
                                   policy=_policy(backend, n_splits))
        assert float(jnp.max(jnp.abs(got - oracle))) < TOL
        assert bool(jnp.all(_argmax(got, a["head"])
                            == _argmax(oracle, a["head"])))


@pytest.mark.parametrize("Hq,Hkv", _HEADS)
@pytest.mark.parametrize("backend", _BACKENDS)
@pytest.mark.parametrize("n_splits", [1, 2])
def test_ring_verify_int8_matches_quantized_oracle(Hq, Hkv, backend,
                                                   n_splits):
    a = _arrays(2, Hq, Hkv, seed=Hq * 20 + n_splits)
    for pos_v in _POS.values():
        pos = jnp.int32(pos_v)
        k_pos = ops.ring_positions(pos - 1, C)
        oracle = ref.verify_attention_ref(a["qv"], a["k"], a["v"], a["kn"],
                                          a["vn"], k_pos, pos,
                                          k_scale=a["ks"], v_scale=a["vs"])
        got = ops.verify_attention(a["qv"], a["k"], a["v"], a["kn"],
                                   a["vn"], pos, k_scale=a["ks"],
                                   v_scale=a["vs"],
                                   policy=_policy(backend, n_splits))
        assert float(jnp.max(jnp.abs(got - oracle))) < TOL
        assert bool(jnp.all(_argmax(got, a["head"])
                            == _argmax(oracle, a["head"])))


@pytest.mark.parametrize("Hq,Hkv", _HEADS)
@pytest.mark.parametrize("backend", _BACKENDS)
@pytest.mark.parametrize("n_splits", [1, 2])
def test_paged_decode_int8_matches_quantized_oracle(Hq, Hkv, backend,
                                                    n_splits):
    a = _arrays(3, Hq, Hkv, seed=Hq * 30 + n_splits)
    pos = jnp.asarray([3, 37, 79], jnp.int32)          # ragged occupancy
    oracle = ref.paged_decode_attention_ref(a["q1"], a["kp"], a["vp"],
                                            a["bt"], pos, k_scale=a["kps"],
                                            v_scale=a["vps"])
    got = ops.paged_decode_attention(a["q1"], a["kp"], a["vp"], a["bt"],
                                     pos, k_scale=a["kps"],
                                     v_scale=a["vps"],
                                     policy=_policy(backend, n_splits))
    assert float(jnp.max(jnp.abs(got - oracle))) < TOL
    assert bool(jnp.all(_argmax(got, a["head"])
                        == _argmax(oracle, a["head"])))


@pytest.mark.parametrize("Hq,Hkv", _HEADS)
@pytest.mark.parametrize("backend", _BACKENDS)
@pytest.mark.parametrize("n_splits", [1, 2])
def test_paged_verify_int8_matches_quantized_oracle(Hq, Hkv, backend,
                                                    n_splits):
    a = _arrays(3, Hq, Hkv, seed=Hq * 40 + n_splits)
    pos = jnp.asarray([5, 41, 76], jnp.int32)
    oracle = ref.paged_verify_attention_ref(a["qv"], a["kp"], a["vp"],
                                            a["kn"], a["vn"], a["bt"], pos,
                                            k_scale=a["kps"],
                                            v_scale=a["vps"])
    got = ops.paged_verify_attention(a["qv"], a["kp"], a["vp"], a["kn"],
                                     a["vn"], a["bt"], pos,
                                     k_scale=a["kps"], v_scale=a["vps"],
                                     policy=_policy(backend, n_splits))
    assert float(jnp.max(jnp.abs(got - oracle))) < TOL
    assert bool(jnp.all(_argmax(got, a["head"])
                        == _argmax(oracle, a["head"])))


def test_ring_decode_int8_window_and_softcap():
    a = _arrays(2, 4, 2, seed=5)
    pos = jnp.int32(_POS["wrap"])
    k_pos = ops.ring_positions(pos, C)
    for kw in ({"window": 24}, {"logit_cap": 30.0}):
        oracle = ref.decode_attention_ref(a["q1"], a["k"], a["v"], k_pos,
                                          pos, k_scale=a["ks"],
                                          v_scale=a["vs"], **kw)
        got = ops.decode_attention(a["q1"], a["k"], a["v"], pos,
                                   k_scale=a["ks"], v_scale=a["vs"],
                                   policy=_policy("pallas_interpret", 2),
                                   **kw)
        assert float(jnp.max(jnp.abs(got - oracle))) < TOL


# --------------------------------------------------------------------------
# engine level: the hierarchy must not move a single greedy token
# --------------------------------------------------------------------------
# (the hypothesis property test for the two-tier pool lives in
# tests/test_properties.py::test_two_tier_invariants_under_random_ops,
# behind the dev-extra hypothesis gate)
N_SLOTS, PAGE, CHUNK = 4, 8, 8
SHARED, SUFFIX, GEN = 44, (4, 12), (6, 16)
MAX_LEN = SHARED + SUFFIX[1] + GEN[1]
FULL_PAGES = N_SLOTS + 2 * -(-MAX_LEN // PAGE)       # roomy: no pressure
TIGHT_PAGES = N_SLOTS + -(-MAX_LEN // PAGE) + 2      # ~1 context + slack


@functools.lru_cache(maxsize=1)
def _tier_model():
    from repro.configs import get_arch
    from repro.models import transformer as tfm
    spec = get_arch("smollm-135m")
    cfg = dataclasses.replace(spec.smoke, d_model=64, d_ff=128, head_dim=16)
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _trace(cfg):
    from repro.serving import poisson_trace
    return poisson_trace(8, rate_per_step=0.5, seed=23,
                         vocab_size=cfg.vocab_size, prompt_len=SUFFIX,
                         max_new_tokens=GEN, shared_prefix_len=SHARED,
                         prompt_pools=1)


def _run(**kw):
    from repro.serving import EngineConfig, ServeEngine
    cfg, params = _tier_model()
    kw.setdefault("n_pages", FULL_PAGES)
    ecfg = EngineConfig(n_slots=N_SLOTS, page_size=PAGE, max_len=MAX_LEN,
                        decode_chunk=CHUNK, **kw)
    eng = ServeEngine(cfg, ecfg, params)
    rep = eng.run([dataclasses.replace(r) for r in _trace(cfg)])
    return eng, rep


def _streams(rep):
    return [list(np.asarray(r.tokens).ravel()) for r in rep.results]


@functools.lru_cache(maxsize=1)
def _baseline():
    return _run()                    # bf16, roomy pool, no tier


def test_default_path_has_no_tier_state():
    eng, rep = _baseline()
    for c in eng.cache["units"].values():
        assert "k_scale" not in c and "v_scale" not in c
    assert not eng.kv.host_tier and eng.kv._fetch_page is None
    assert rep.transfer_j == 0.0
    assert rep.n_demotions == 0 and rep.n_promotions == 0


def test_int8_engine_streams_match_bf16():
    eng, rep = _run(kv_dtype="int8")
    assert _streams(rep) == _streams(_baseline()[1])
    for c in eng.cache["units"].values():
        assert c["k"].dtype == jnp.int8 and c["v"].dtype == jnp.int8
        assert c["k_scale"].dtype == jnp.float32
        assert c["v_scale"].dtype == jnp.float32


@pytest.mark.parametrize("kv_dtype", ["bfloat16", "int8"])
def test_host_tier_streams_match_and_charge_transfer(kv_dtype):
    eng, rep = _run(kv_dtype=kv_dtype, n_pages=TIGHT_PAGES, host_tier=True,
                    host_pages=16)
    assert _streams(rep) == _streams(_baseline()[1])
    assert rep.n_demotions > 0                       # tight pool paged out
    assert rep.transfer_j > 0.0
    assert rep.energy_j >= rep.transfer_j            # folded into the ledger
    assert eng.kv.verify_invariants() == []


def test_crash_restore_preserves_host_tier_pages():
    """Crash after pages demoted: the snapshot must carry the host-tier
    blobs, and the restored engine's streams must stay bit-identical to
    the fault-free roomy-pool baseline."""
    from repro.runtime.chaos import FaultInjector
    from repro.serving import EngineConfig, EngineCrash, ServeEngine
    cfg, params = _tier_model()
    ecfg = EngineConfig(n_slots=N_SLOTS, page_size=PAGE, max_len=MAX_LEN,
                        decode_chunk=CHUNK, n_pages=TIGHT_PAGES,
                        kv_dtype="int8", host_tier=True, host_pages=16)
    inj = FaultInjector(seed=0)
    inj.schedule("engine_crash", 12)
    snap = tempfile.mkdtemp(prefix="kvtier_chaos_")
    eng = ServeEngine(cfg, ecfg, params, injector=inj,
                      snapshot_dir=snap, snapshot_every=2)
    with pytest.raises(EngineCrash):
        eng.run([dataclasses.replace(r) for r in _trace(cfg)])
    eng2 = ServeEngine.restore(cfg, ecfg, params, snap,
                               injector=inj, snapshot_every=2)
    assert eng2.kv.n_host_used() > 0         # demoted pages survived
    rep = eng2.resume()
    assert rep.n_restores == 1
    assert _streams(rep) == _streams(_baseline()[1])
    assert rep.n_demotions > 0
    assert eng2.kv.verify_invariants() == []


def test_kv_dtype_fallback_warns_once():
    """int8 on a family whose cache has no full-length k/v page pools
    (``int8_paged_blockers`` names the feature) degrades to the cache
    dtype with ONE RuntimeWarning, not per-engine spam.  musicgen —
    blocked before the zoo paged rework because the old gate keyed on the
    speculative seam — now carries real scale rows: its pools are plain
    GQA, only the token side is multi-codebook."""
    from repro.configs import get_arch
    from repro.models import transformer as tfm
    from repro.serving import EngineConfig, ServeEngine
    spec = get_arch("h2o-danube-3-4b")
    cfg = dataclasses.replace(spec.smoke, d_model=64, d_ff=128, head_dim=16)
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    ops._KV_DTYPE_FALLBACK_WARNED.discard(cfg.name)
    ecfg = EngineConfig(n_slots=2, page_size=8, max_len=32, kv_dtype="int8")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        eng = ServeEngine(cfg, ecfg, params)
        ServeEngine(cfg, ecfg, params)
    hits = [w for w in rec if "kv_dtype=int8" in str(w.message)]
    assert len(hits) == 1 and issubclass(hits[0].category, RuntimeWarning)
    assert "sliding_window" in str(hits[0].message)
    for c in eng.cache["units"].values():    # degraded: no scale rows
        assert "k_scale" not in c

    mg = get_arch("musicgen-medium")
    cfg_mg = dataclasses.replace(mg.smoke, d_model=64, d_ff=128, head_dim=16)
    params_mg, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg_mg)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        eng_mg = ServeEngine(cfg_mg, ecfg, params_mg)
    assert not [w for w in rec if "kv_dtype=int8" in str(w.message)]
    assert all("k_scale" in c for c in eng_mg.cache["units"].values())
