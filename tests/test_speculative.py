"""Speculative-decoding validation: greedy speculative decode must emit
token-for-token the plain fused loop's stream for every drafter and every
K — including across ring wrap-around and on the paged layout — plus
drafter unit behaviour, partial-commit correctness, and sampling-mode
determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as tfm
from repro.runtime.speculate import (NgramDrafter, RepeatDrafter,
                                     ReplayDrafter, get_drafter)
from repro.runtime.steps import (StepConfig, make_decode_loop,
                                 make_prefill_step,
                                 make_speculative_decode_loop)

STEP_CFG = StepConfig(remat="none")


@pytest.fixture(scope="module")
def smollm():
    cfg = get_arch("smollm-135m").smoke
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prefilled(cfg, params, max_len):
    """Repetitive prompt (ngram-friendly) -> (cache, first token, prompts)."""
    prefill = jax.jit(make_prefill_step(cfg, STEP_CFG, max_len=max_len))
    pat = jax.random.randint(jax.random.PRNGKey(5), (2, 4), 0, cfg.vocab_size)
    prompts = jnp.tile(pat, (1, 2))
    last_logits, cache = prefill(params, {"inputs": prompts})
    tok0 = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
    return cache, tok0, prompts


def _flatten(toks, counts):
    """Concatenate each row's kept tokens ((B, steps, Q), (B, steps))."""
    out = []
    for b in range(toks.shape[0]):
        row = []
        for s in range(toks.shape[1]):
            row.extend(toks[b, s, :counts[b, s]].tolist())
        out.append(row)
    return out


def _seeded_state(drafter, prompts, tok0):
    ds = drafter.init_state(prompts.shape[0])
    drafter.seed_batch(ds, np.asarray(prompts), np.asarray(tok0))
    return {k: jnp.asarray(v) for k, v in ds.items()}


# exactness matrix: every drafter x K x {deep ring (no wrap), tiny ring
# (wraps mid-run)}; n_steps * (K+1) bounds the tokens one run can emit
EXACT_KS = (1, 2, 4)
N_STEPS = 8


@pytest.mark.parametrize("max_len", [64, 16])   # 16 wraps the ring mid-run
@pytest.mark.parametrize("k", EXACT_KS)
@pytest.mark.parametrize("drafter_name", ["ngram", "repeat", "replay"])
def test_greedy_speculative_exact(smollm, max_len, k, drafter_name):
    """Greedy speculative == plain fused loop, token for token, for every
    emitted token — whatever the drafter proposes and however much of it
    is rejected."""
    cfg, params = smollm
    cache, tok0, prompts = _prefilled(cfg, params, max_len)
    gen_ref = N_STEPS * (k + 1)
    plain = jax.jit(make_decode_loop(cfg, STEP_CFG, n_tokens=gen_ref))
    ref_toks = np.asarray(plain(params, cache, tok0)[0])

    if drafter_name == "replay":
        drafter = ReplayDrafter(k, ref_toks)
    elif drafter_name == "ngram":
        drafter = NgramDrafter(k, hist_len=32)
    else:
        drafter = RepeatDrafter(k)
    loop = jax.jit(make_speculative_decode_loop(
        cfg, STEP_CFG, n_steps=N_STEPS, drafter=drafter))
    ds = _seeded_state(drafter, prompts, tok0)
    toks, counts, cache2, _ = loop(params, cache, tok0, ds)
    toks, counts = np.asarray(toks), np.asarray(counts)

    # the ring loop advances the batch in lockstep: counts agree across B
    assert (counts == counts[0]).all()
    assert (counts >= 1).all() and (counts <= k + 1).all()
    flat = _flatten(toks, counts)
    n = len(flat[0])
    np.testing.assert_array_equal(
        np.asarray(flat), ref_toks[:, :n],
        err_msg=f"max_len={max_len} K={k} {drafter_name}")
    # the cache advanced exactly one position per emitted token
    assert int(cache2["pos"]) == int(cache["pos"]) + n
    if drafter_name == "replay":
        # perfect drafts: every step must emit K+1 tokens (the CI canary
        # invariant — any verify/commit bug breaks this before anything else)
        assert (counts == k + 1).all()


def test_ngram_drafter_lookup():
    """The prompt-lookup rule itself: followers of the most recent earlier
    occurrence, fallback to repeat when absent."""
    d = NgramDrafter(3, hist_len=16)
    ds = d.init_state(2)
    d.seed_row(ds, 0, [7, 1, 2, 3, 9, 4])   # last=4; no earlier 4 -> repeat
    d.seed_row(ds, 1, [5, 1, 2, 3, 5])      # last=5; earlier 5 -> 1, 2, 3
    state = {k: jnp.asarray(v) for k, v in ds.items()}
    drafts = np.asarray(d.propose(state, jnp.asarray([4, 5])))
    np.testing.assert_array_equal(drafts[0], [4, 4, 4])
    np.testing.assert_array_equal(drafts[1], [1, 2, 3])
    # observe folds emitted tokens: history ... 5 1 2 -> last=2 follows with 3
    state = d.observe(state, jnp.asarray([[9, 9, 9, 9], [1, 2, 0, 0]]),
                      jnp.asarray([0, 2]))
    drafts = np.asarray(d.propose(state, jnp.asarray([4, 2])))
    np.testing.assert_array_equal(drafts[1], [3, 5, 1])
    # row 0 saw count=0: unchanged, still no earlier 4
    np.testing.assert_array_equal(drafts[0], [4, 4, 4])


def test_ngram_drafter_long_history_wraps():
    """Seeding more tokens than hist_len keeps the most recent ones."""
    d = NgramDrafter(2, hist_len=8)
    ds = d.init_state(1)
    # 9 tokens, hist 8: the leading 1 falls out, the earlier 111 survives
    d.seed_row(ds, 0, [1, 2, 3, 111, 112, 113, 9, 8, 111])
    state = {k: jnp.asarray(v) for k, v in ds.items()}
    drafts = np.asarray(d.propose(state, jnp.asarray([111])))
    np.testing.assert_array_equal(drafts[0], [112, 113])


def test_replay_drafter_exhaustion_falls_back():
    """Past the recorded stream the replay drafter degrades to repeat
    instead of reading junk."""
    d = ReplayDrafter(3, np.asarray([[10, 11]]))
    state = {k: jnp.asarray(v) for k, v in d.init_state(1).items()}
    drafts = np.asarray(d.propose(state, jnp.asarray([9])))
    np.testing.assert_array_equal(drafts[0], [10, 11, 9])
    state = d.observe(state, jnp.asarray([[10, 11, 0, 0]]), jnp.asarray([2]))
    drafts = np.asarray(d.propose(state, jnp.asarray([11])))
    np.testing.assert_array_equal(drafts[0], [11, 11, 11])


def test_get_drafter_factory():
    assert isinstance(get_drafter("ngram", 2), NgramDrafter)
    assert isinstance(get_drafter("repeat", 3), RepeatDrafter)
    with pytest.raises(ValueError):
        get_drafter("replay", 2)            # test-only: needs a stream
    with pytest.raises(ValueError):
        get_drafter("nope", 2)


def test_speculative_gate_rejects_unsupported():
    """Families whose caches cannot re-verify (ssm) are rejected loudly."""
    cfg = get_arch("mamba2-370m").smoke
    with pytest.raises(ValueError):
        make_speculative_decode_loop(cfg, STEP_CFG, n_steps=2,
                                     drafter=RepeatDrafter(2))
    assert not tfm.supports_speculative(cfg)
    assert tfm.supports_speculative(get_arch("smollm-135m").smoke)


def test_sampling_speculative_deterministic(smollm):
    """Temperature rejection-sampling: same key -> same stream; different
    key -> different stream (the in-scan PRNG discipline)."""
    cfg, params = smollm
    cache, tok0, prompts = _prefilled(cfg, params, 64)
    drafter = NgramDrafter(2, hist_len=32)
    loop = jax.jit(make_speculative_decode_loop(
        cfg, STEP_CFG, n_steps=6, drafter=drafter, greedy=False,
        temperature=0.8))
    ds = _seeded_state(drafter, prompts, tok0)
    a, ca, _, _ = loop(params, cache, tok0, ds, jax.random.PRNGKey(7))
    b, cb, _, _ = loop(params, cache, tok0, ds, jax.random.PRNGKey(7))
    c, cc, _, _ = loop(params, cache, tok0, ds, jax.random.PRNGKey(8))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))
    assert np.any(np.asarray(a) != np.asarray(c))
    # emitted counts stay in [1, K+1] whatever the acceptance draw
    assert (np.asarray(ca) >= 1).all() and (np.asarray(ca) <= 3).all()


def test_verify_commit_partial_prefix(smollm):
    """Committing only part of a verified block then re-verifying from the
    accepted prefix reproduces the sequential stream — the no-rollback
    invariant behind in-scan accept/reject."""
    from repro.runtime.steps import make_run_ctx
    cfg, params = smollm
    ctx = make_run_ctx(cfg, None, STEP_CFG)
    cache, tok0, _ = _prefilled(cfg, params, 16)     # tiny ring: wraps
    # sequential ground truth
    seq_logits = []
    c, t = cache, tok0
    stream = [np.asarray(tok0[:, 0])]
    for _ in range(8):
        lg, c = tfm.decode_step(params, c, t, cfg, ctx)
        seq_logits.append(np.asarray(lg[:, -1]))
        t = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
        stream.append(np.asarray(t[:, 0]))
    fed = jnp.stack(stream[:4], axis=1)              # (B, 4)
    lg, pend = tfm.verify_step(params, cache, fed, cfg, ctx)
    for j in range(4):
        np.testing.assert_allclose(np.asarray(lg[:, j]), seq_logits[j],
                                   atol=2e-4, rtol=2e-4)
    c2 = tfm.commit_spec(cache, pend, jnp.asarray(1), cfg)  # rows 0..1 only
    assert int(c2["pos"]) == int(cache["pos"]) + 2
    fed2 = jnp.stack(stream[2:6], axis=1)
    lg2, _ = tfm.verify_step(params, c2, fed2, cfg, ctx)
    for j in range(4):
        np.testing.assert_allclose(np.asarray(lg2[:, j]), seq_logits[2 + j],
                                   atol=2e-4, rtol=2e-4)
