"""Compressed-latent MLA paged decode contract tests.

The MLA sweep stores ONE latent row per token (R = r_kv + d_rope lanes)
shared by every q head: scores are one dot of the latent query
``[q_abs | q_rope]`` against the full row, the value read is the
``[:r_kv]`` slice of the SAME row, and the two-stage path emits per-split
``(partial, lse)`` merged by the one shared ``merge_kv_splits_pallas``
stage-2 kernel.  Every case sweeps kv_splits x ragged pos x partial
occupancy against the naive ``ref.mla_decode_paged_ref`` oracle on both
the jnp and interpret-mode Pallas backends, plus the stage-1 partial/LSE
contract against ``ref.mla_decode_split_ref``.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import decode_attention as da
from repro.kernels import ops, ref

B, HQ = 2, 8
R_KV, D_ROPE = 32, 16
R = R_KV + D_ROPE
PS, NB = 4, 8                       # 8 pages of 4 -> 32 logical rows
SPLITS = [1, 2, 5]                  # 2 and 5 do not divide 8 blocks evenly
SCALE = (2 * R_KV / HQ) ** -0.5
TOL = 5e-6

# per-request absolute positions: full cache / ragged / nearly empty (the
# partial-occupancy row exercises whole-split pruning: splits past pos
# must emit the empty-split LSE sentinel, not garbage partials)
_POS = {
    "full": [NB * PS - 1, NB * PS - 1],
    "ragged": [NB * PS - 1, 9],
    "partial": [6, 2],
}


def _arrays(seed=0):
    rng = np.random.default_rng(seed)
    n_pages = B * NB + 3                         # spare pages stay unread
    q = jnp.asarray(rng.standard_normal((B, 1, HQ, R)), jnp.float32)
    pages = jnp.asarray(rng.standard_normal((n_pages, PS, R)), jnp.float32)
    tables = jnp.asarray(rng.permutation(n_pages)[:B * NB].reshape(B, NB),
                         jnp.int32)
    head = jnp.asarray(rng.standard_normal((HQ * R_KV, 64)), jnp.float32)
    return q, pages, tables, head


def _argmax(out, head):
    return jnp.argmax(out.reshape(B, -1) @ head, axis=-1)


@pytest.mark.parametrize("pos_kind", list(_POS))
@pytest.mark.parametrize("n_splits", SPLITS)
def test_mla_paged_jnp_matches_oracle(pos_kind, n_splits):
    q, pages, tables, head = _arrays(seed=n_splits)
    pos = jnp.asarray(_POS[pos_kind], jnp.int32)
    want = ref.mla_decode_paged_ref(q, pages, tables, pos, r_kv=R_KV,
                                    scale=SCALE)
    got = ops.mla_decode_paged_jnp(q, pages, tables, pos, r_kv=R_KV,
                                   scale=SCALE, n_splits=n_splits)
    assert float(jnp.max(jnp.abs(got - want))) < TOL
    assert bool(jnp.all(_argmax(got, head) == _argmax(want, head)))


@pytest.mark.parametrize("pos_kind", list(_POS))
@pytest.mark.parametrize("n_splits", SPLITS)
def test_mla_paged_pallas_interpret_matches_oracle(pos_kind, n_splits):
    q, pages, tables, head = _arrays(seed=10 + n_splits)
    pos = jnp.asarray(_POS[pos_kind], jnp.int32)
    want = ref.mla_decode_paged_ref(q, pages, tables, pos, r_kv=R_KV,
                                    scale=SCALE)
    got = da.mla_paged_decode_attention_pallas(
        q, pages, tables, pos, r_kv=R_KV, scale=SCALE, n_splits=n_splits,
        interpret=True)
    assert float(jnp.max(jnp.abs(got - want))) < TOL
    assert bool(jnp.all(_argmax(got, head) == _argmax(want, head)))


@pytest.mark.parametrize("pos_kind", list(_POS))
@pytest.mark.parametrize("n_splits", [2, 5])
def test_mla_stage1_partials_match_split_oracle(pos_kind, n_splits):
    """The Pallas stage-1 kernel and the split oracle agree split by split
    — partials AND the log-sum-exp rows the shared stage-2 merge consumes
    (empty splits must carry the same masked-LSE sentinel)."""
    q, pages, tables, _ = _arrays(seed=20 + n_splits)
    pos = jnp.asarray(_POS[pos_kind], jnp.int32)
    p_ref, l_ref = ref.mla_decode_split_ref(q, pages, tables, pos,
                                            r_kv=R_KV, n_splits=n_splits,
                                            scale=SCALE)
    p_pal, l_pal = da.mla_paged_decode_attention_pallas_partials(
        q, pages, tables, pos, r_kv=R_KV, n_splits=n_splits, scale=SCALE,
        interpret=True)
    assert p_ref.shape == p_pal.shape and l_ref.shape == l_pal.shape
    assert float(jnp.max(jnp.abs(p_ref - p_pal))) < TOL
    assert float(jnp.max(jnp.abs(l_ref - l_pal))) < TOL


def test_mla_split_merge_recovers_single_stage():
    """Stage-1 partials merged by the SHARED stage-2 kernel reproduce the
    single-stage sweep on the same arrays — the n_splits=1 path stays the
    bit-exactness anchor the engine's greedy streams ride on."""
    q, pages, tables, _ = _arrays(seed=33)
    pos = jnp.asarray(_POS["ragged"], jnp.int32)
    single = da.mla_paged_decode_attention_pallas(
        q, pages, tables, pos, r_kv=R_KV, scale=SCALE, n_splits=1,
        interpret=True)
    p, l = da.mla_paged_decode_attention_pallas_partials(
        q, pages, tables, pos, r_kv=R_KV, n_splits=5, scale=SCALE,
        interpret=True)
    merged = da.merge_kv_splits_pallas(p, l, out_dtype=q.dtype,
                                       interpret=True).transpose(0, 2, 1, 3)
    assert float(jnp.max(jnp.abs(merged - single))) < TOL


def test_mla_decode_paged_dispatch_backends_agree():
    """The ``KernelPolicy.decode`` seam: jnp and interpret-Pallas backends
    (auto-chosen splits included) agree through ``ops.mla_decode_paged``."""
    q, pages, tables, head = _arrays(seed=44)
    pos = jnp.asarray(_POS["ragged"], jnp.int32)
    outs = []
    for backend in ("jnp", "pallas_interpret"):
        for kv_splits in ("auto", 1, 4):
            pol = ops.KernelPolicy(decode=backend, kv_splits=kv_splits)
            outs.append(ops.mla_decode_paged(q, pages, tables, pos,
                                             r_kv=R_KV, scale=SCALE,
                                             policy=pol))
    for o in outs[1:]:
        assert float(jnp.max(jnp.abs(o - outs[0]))) < TOL
        assert bool(jnp.all(_argmax(o, head) == _argmax(outs[0], head)))
