"""Fault-tolerance + compression tests: failure injection -> restore,
elastic re-mesh decision, straggler power-shift, int8 error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import PowerCappedDevice, TPU_V5E, WorkloadProfile
from repro.core.powershift import ClusterNode
from repro.runtime.compress import (compress_residual, dequantize_int8,
                                    init_error_state, quantize_int8)
from repro.control import EventBus, NodeDerated
from repro.runtime.fault import (ServingSupervisor, Supervisor,
                                 SupervisorConfig)


# --------------------------------------------------------------------------
# supervisor
# --------------------------------------------------------------------------
def _trainer(tmp_path, inject=None, n_steps=12, elastic=True):
    """A toy counting 'training' job under supervision."""
    ckpt = CheckpointManager(tmp_path, keep=2)
    state0 = {"x": jnp.zeros(())}
    ckpt.save(state0, 0)

    def step_fn(state, batch):
        return {"x": state["x"] + batch}, {"loss": float(10.0 - state["x"])}

    sup = Supervisor(
        SupervisorConfig(checkpoint_every=4, elastic=elastic),
        save_fn=lambda s, i: ckpt.save(s, i),
        restore_fn=lambda: (ckpt.restore(state0), ckpt.latest_step() or 0))
    sup.register("node-0")
    sup.register("node-1")
    batches = [jnp.asarray(1.0)] * n_steps
    state, report = sup.run(step_fn, state0, batches,
                            inject_failure_at=inject or {})
    return state, report


def test_supervisor_clean_run(tmp_path):
    state, report = _trainer(tmp_path)
    assert report["final_step"] == 12
    assert report["restarts"] == 0
    assert float(state["x"]) == 12.0


def test_supervisor_recovers_from_failure(tmp_path):
    state, report = _trainer(tmp_path, inject={6: "node-1"})
    assert report["restarts"] == 1
    events = [e["event"] for e in report["events"]]
    assert "recovery" in events
    # resumed from the step-4 checkpoint: at most (failure_step - ckpt_step)
    # + 1 batch of work lost, training continued past the failure point
    assert report["final_step"] > 6
    assert float(state["x"]) > 4.0


def test_supervisor_elastic_remesh_decision(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    ckpt.save({"x": jnp.zeros(())}, 0)
    sup = Supervisor(SupervisorConfig(elastic=True),
                     save_fn=lambda s, i: ckpt.save(s, i),
                     restore_fn=lambda: ({"x": jnp.zeros(())}, 0))
    for i in range(8):
        sup.register(f"n{i}")
    sup.workers["n3"].alive = False
    decision = sup.handle_failure(["n3"])
    assert decision["action"] == "remesh"
    assert decision["new_dp"] == 4          # 7 alive -> largest pow2 = 4


def test_supervisor_abort_after_budget(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    ckpt.save({"x": jnp.zeros(())}, 0)
    sup = Supervisor(SupervisorConfig(max_restarts=1, elastic=False),
                     save_fn=lambda s, i: None,
                     restore_fn=lambda: ({"x": jnp.zeros(())}, 0))
    sup.register("n0")
    sup.handle_failure(["n0"])
    assert sup.handle_failure(["n0"])["action"] == "abort"


def test_supervisor_failure_detected_via_liveness(tmp_path):
    """Injection stalls the node's heartbeat instead of flagging it dead
    directly — recovery proves check_liveness is wired into run()."""
    state, report = _trainer(tmp_path, inject={6: "node-1"})
    events = [e["event"] for e in report["events"]]
    assert "node_dead" in events                  # liveness saw the silence
    assert events.index("node_dead") < events.index("recovery")


def test_supervisor_restores_exactly_once_per_failure(tmp_path):
    """handle_failure restores the checkpoint; run() must reuse that state
    via take_restored instead of paying (and counting) a second restore."""
    from repro.checkpoint import CheckpointManager
    ckpt = CheckpointManager(tmp_path, keep=2)
    state0 = {"x": jnp.zeros(())}
    ckpt.save(state0, 0)
    n_restores = {"n": 0}

    def restore_fn():
        n_restores["n"] += 1
        return ckpt.restore(state0), ckpt.latest_step() or 0

    sup = Supervisor(SupervisorConfig(checkpoint_every=4),
                     save_fn=lambda s, i: ckpt.save(s, i),
                     restore_fn=restore_fn)
    sup.register("node-0")
    sup.register("node-1")
    step_fn = lambda s, b: ({"x": s["x"] + b}, {"loss": 0.0})
    _, report = sup.run(step_fn, state0, [jnp.asarray(1.0)] * 12,
                        inject_failure_at={6: "node-1"})
    assert report["restarts"] == 1
    assert n_restores["n"] == 1                   # once, not once-per-caller


def test_supervisor_heartbeat_auto_registers_unknown_node():
    sup = Supervisor(SupervisorConfig(), save_fn=lambda s, i: None,
                     restore_fn=lambda: (None, 0))
    sup.heartbeat("joiner", step=3, latency_s=0.5)   # elastic scale-up
    assert "joiner" in sup.workers and sup.workers["joiner"].step == 3
    assert any(e["event"] == "auto_register" for e in sup.events)


def test_serving_supervisor_publishes_derate():
    """Chunk-wall inflation becomes a NodeDerated on the control bus: the
    serving half of the FROST straggler loop."""
    bus = EventBus()
    derated = bus.tap(NodeDerated)
    sup = ServingSupervisor(bus=bus, node_id="serve-0",
                            baseline_wall_s=1.0, ewma=0.0)
    sup.on_heartbeat(4, 1.0)                      # healthy: no publish
    assert not derated
    for step in range(8, 24, 4):
        sup.on_heartbeat(step, 2.0)               # chunks run 2x slow
    assert derated and derated[-1].derate == pytest.approx(0.5)
    assert sup.workers["serve-0"].derate == pytest.approx(0.5)
    n = len(derated)
    sup.on_heartbeat(24, 2.0)                     # unchanged: delta-gated
    assert len(derated) == n


def test_serving_supervisor_tick_fires_on_dead():
    t = {"now": 0.0}
    dead_nodes = []
    sup = ServingSupervisor(SupervisorConfig(heartbeat_timeout_s=5.0),
                            on_dead=dead_nodes.append,
                            clock=lambda: t["now"])
    sup.on_heartbeat(0, 0.01)
    t["now"] = 3.0
    assert sup.tick() == [] and not dead_nodes    # within the window
    t["now"] = 10.0                               # engine went silent
    assert sup.tick() == ["serve-0"]
    assert dead_nodes == ["serve-0"]


def test_straggler_detection_and_rebalance(tmp_path):
    sup = Supervisor(SupervisorConfig(straggler_threshold=1.2),
                     save_fn=lambda s, i: None,
                     restore_fn=lambda: (None, 0))
    sup.register("fast0"); sup.register("fast1"); sup.register("slow")
    sup.heartbeat("fast0", 1, 1.0)
    sup.heartbeat("fast1", 1, 1.05)
    sup.heartbeat("slow", 1, 1.6)
    stragglers, lat = sup.straggler_report()
    assert stragglers == ["slow"]
    # FROST power-shift: derated node must receive a higher cap
    wl = WorkloadProfile(name="w", flops_per_step=5e12, hbm_bytes_per_step=2e9)
    nodes = [ClusterNode("fast0", PowerCappedDevice(TPU_V5E), wl),
             ClusterNode("slow", PowerCappedDevice(TPU_V5E, derate=0.75), wl)]
    plan = sup.rebalance_power(nodes, budget_w=1.8 * TPU_V5E.tdp_w)
    caps = {a.node_id: a.cap for a in plan.allocations}
    assert caps["slow"] >= caps["fast0"]


# --------------------------------------------------------------------------
# compression
# --------------------------------------------------------------------------
def test_quantize_roundtrip_bounds():
    x = jnp.asarray([-3.0, -0.1, 0.0, 0.5, 2.9])
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) / 2 + 1e-7


def test_error_feedback_telescopes():
    """Sum of dequantized values + final residual == sum of true values —
    the telescoping identity that preserves SGD convergence."""
    rng = np.random.default_rng(0)
    xs = [jnp.asarray(rng.normal(size=16), jnp.float32) for _ in range(50)]
    e = jnp.zeros(16)
    total_sent = jnp.zeros(16)
    for x in xs:
        q, scale, e = compress_residual(x + e)
        total_sent = total_sent + dequantize_int8(q, scale)
    true_total = sum(np.asarray(x) for x in xs)
    # residual e is the only unsent mass
    np.testing.assert_allclose(np.asarray(total_sent + e), true_total,
                               rtol=1e-5, atol=1e-5)


def test_compressed_psum_single_device_mesh():
    """compressed_psum over a size-1 axis == identity (mean of one)."""
    mesh = jax.make_mesh((1,), ("pod",))
    from jax.sharding import PartitionSpec as P
    from repro.runtime.compress import compressed_psum

    g = {"w": jnp.asarray([0.5, -1.5, 2.0])}
    e = init_error_state(g)

    def inner(g, e):
        return compressed_psum(g, "pod", e)

    from repro.models.common import shard_map
    out, err = shard_map(
        inner, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), g),) * 2,
        out_specs=(jax.tree.map(lambda _: P(), g),) * 2,
        check=False)(g, e)
    np.testing.assert_allclose(np.asarray(out["w"]), [0.5, -1.5, 2.0],
                               atol=0.02)
    # error feedback captured the quantization residual
    np.testing.assert_allclose(np.asarray(out["w"] + err["w"]),
                               [0.5, -1.5, 2.0], atol=1e-6)
