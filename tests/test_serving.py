"""Continuous-batching engine validation: mid-stream join/finish parity
against solo runs, paged-loop occupancy isolation, page-manager invariants,
admission control, and sampling determinism of the fused decode loops."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as tfm
from repro.runtime.steps import StepConfig, make_decode_loop
from repro.serving import (EnergyAwareAdmission, EngineConfig, PagedKVCache,
                           Request, ServeEngine, batch_trace, poisson_trace)


@pytest.fixture(scope="module")
def smollm():
    cfg = get_arch("smollm-135m").smoke
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


ECFG = EngineConfig(n_slots=2, page_size=4, max_len=48, decode_chunk=4)


def test_engine_join_finish_parity(smollm):
    """Requests joining and finishing mid-decode produce EXACTLY the tokens
    of running each request alone: slot masking, page isolation, and the
    prefill-on-join bucket make other slots' traffic invisible."""
    cfg, params = smollm
    reqs = poisson_trace(5, rate_per_step=0.3, seed=7,
                         vocab_size=cfg.vocab_size, prompt_len=(3, 13),
                         max_new_tokens=(4, 10))
    rep = ServeEngine(cfg, ECFG, params).run(reqs)
    # the trace actually interleaves: some request waited for a slot
    assert any(r.wait_steps > 0 for r in rep.results)
    assert all(r.n_tokens == r.max_new_tokens for r in rep.results)
    for r, req in zip(rep.results, reqs):
        solo = ServeEngine(cfg, ECFG, params).run(
            [dataclasses.replace(req, arrival_step=0)])
        np.testing.assert_array_equal(
            np.asarray(r.tokens), np.asarray(solo.results[0].tokens),
            err_msg=f"rid {r.rid}")


def test_engine_eos_frees_slot(smollm):
    """EOS mid-chunk truncates the request, frees its slot/pages, and the
    next queued request takes the slot."""
    cfg, params = smollm
    base = batch_trace(3, seed=5, vocab_size=cfg.vocab_size, prompt_len=6,
                       max_new_tokens=12)
    probe = ServeEngine(cfg, ECFG, params).run([base[0]])
    tokens = probe.results[0].tokens
    # pick an "EOS" whose FIRST occurrence is mid-stream (greedy smoke
    # models repeat tokens, so scan rather than index blindly)
    k = next(i for i in range(1, len(tokens)) if tokens[i] not in tokens[:i])
    eos = tokens[k]
    reqs = [dataclasses.replace(base[0], eos_id=eos)] + base[1:]
    rep = ServeEngine(cfg, ECFG, params).run(reqs)
    r0 = rep.results[0]
    assert r0.finish_reason == "eos"
    assert r0.n_tokens == k + 1 and r0.tokens[-1] == eos
    assert all(r.n_tokens == r.max_new_tokens for r in rep.results[1:])


def test_engine_report_accounting(smollm):
    """Occupancy, kept-vs-computed tokens, and occupied-slots-only energy
    attribution add up."""
    cfg, params = smollm
    reqs = poisson_trace(4, rate_per_step=0.15, seed=2,
                         vocab_size=cfg.vocab_size, prompt_len=(4, 10),
                         max_new_tokens=(3, 8))
    energy_per_chunk = 2.5
    rep = ServeEngine(cfg, ECFG, params,
                      on_chunk=lambda s: energy_per_chunk).run(reqs)
    assert rep.tokens_computed >= rep.tokens_kept > 0
    assert 0.0 < rep.occupancy <= 1.0
    assert rep.energy_j == pytest.approx(energy_per_chunk * rep.n_chunks)
    # every chunk's joules land on the requests that kept its tokens
    assert sum(r.energy_j for r in rep.results) == pytest.approx(rep.energy_j)
    # kept tokens = everything the results hold minus the prefill-sampled one
    assert sum(r.n_tokens - 1 for r in rep.results) == rep.tokens_kept


@pytest.mark.parametrize("drafter,spec_k", [("ngram", 2), ("ngram", 4),
                                            ("repeat", 2)])
def test_engine_speculative_token_parity(smollm, drafter, spec_k):
    """Speculative engine mode emits EXACTLY the plain engine's per-request
    token streams (greedy): per-slot accept counts, masked paged commits,
    drafter-state mirrors, and variable-token harvest change only how fast
    tokens arrive, never which tokens."""
    cfg, params = smollm
    reqs = poisson_trace(5, rate_per_step=0.3, seed=7,
                         vocab_size=cfg.vocab_size, prompt_len=(3, 13),
                         max_new_tokens=(4, 10))
    plain = ServeEngine(cfg, ECFG, params).run(reqs)
    ecfg = dataclasses.replace(ECFG, spec_k=spec_k, drafter=drafter)
    rep = ServeEngine(cfg, ecfg, params).run(reqs)
    for r, rp in zip(rep.results, plain.results):
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      np.asarray(rp.tokens),
                                      err_msg=f"rid {r.rid}")
    # fewer sweeps for the same tokens is the whole point
    assert rep.n_chunks <= plain.n_chunks
    assert rep.spec_k == spec_k
    assert rep.drafts_proposed > 0
    assert 0.0 <= rep.acceptance_rate <= 1.0
    # kept/slot-sweep: can dip below 1.0 when the device overruns finished
    # requests, never above K+1
    assert 0.0 < rep.tokens_per_step <= spec_k + 1
    assert rep.j_per_accepted_token == rep.j_per_token


def test_engine_speculative_eos_and_energy(smollm):
    """EOS truncation and occupied-slots-only energy attribution survive
    variable tokens-per-slot-per-step harvesting."""
    cfg, params = smollm
    base = batch_trace(3, seed=5, vocab_size=cfg.vocab_size, prompt_len=6,
                       max_new_tokens=12)
    probe = ServeEngine(cfg, ECFG, params).run([base[0]])
    tokens = probe.results[0].tokens
    k = next(i for i in range(1, len(tokens)) if tokens[i] not in tokens[:i])
    eos = tokens[k]
    reqs = [dataclasses.replace(base[0], eos_id=eos)] + base[1:]
    ecfg = dataclasses.replace(ECFG, spec_k=2)
    rep = ServeEngine(cfg, ecfg, params,
                      on_chunk=lambda s: 2.5).run(reqs)
    r0 = rep.results[0]
    assert r0.finish_reason == "eos"
    assert r0.n_tokens == k + 1 and r0.tokens[-1] == eos
    assert all(r.n_tokens == r.max_new_tokens for r in rep.results[1:])
    assert rep.energy_j == pytest.approx(2.5 * rep.n_chunks)
    assert sum(r.energy_j for r in rep.results) == pytest.approx(rep.energy_j)


def test_engine_report_zero_guards(smollm):
    """Empty runs (no requests / no kept tokens) keep every report figure
    finite — 0.0, not NaN/inf leaking into benchmark CSVs."""
    from repro.serving import EngineReport
    cfg, params = smollm
    rep = ServeEngine(cfg, ECFG, params).run([])
    assert rep.tok_per_s == 0.0
    assert rep.j_per_token == 0.0
    assert rep.acceptance_rate == 0.0
    assert rep.tokens_per_step == 0.0
    assert rep.latency_percentiles((50, 95)) == {50: 0.0, 95: 0.0}
    assert rep.occupancy == 0.0
    blank = EngineReport(results=[])
    for v in (blank.tok_per_s, blank.j_per_token, blank.j_per_accepted_token,
              blank.acceptance_rate, blank.tokens_per_step,
              *blank.latency_percentiles().values()):
        assert v == 0.0 and np.isfinite(v)


def test_prefix_sharing_token_parity(smollm):
    """Prefix sharing is invisible in the output: on a shared-prefix trace
    the sharing engine emits EXACTLY the no-sharing engine's per-request
    greedy streams while prefilling far fewer prompt tokens.  The 11-token
    shared head over page_size 4 ends mid-page, so the copy-on-write path
    (partial shared page duplicated into a private page) is exercised."""
    cfg, params = smollm
    reqs = poisson_trace(6, rate_per_step=0.3, seed=7,
                         vocab_size=cfg.vocab_size, prompt_len=(3, 9),
                         max_new_tokens=(4, 10), shared_prefix_len=11,
                         prompt_pools=2)
    ecfg = dataclasses.replace(ECFG, max_len=64)
    share = ServeEngine(cfg, dataclasses.replace(ecfg, prefix_cache=True),
                        params).run(reqs)
    plain = ServeEngine(cfg, dataclasses.replace(ecfg, prefix_cache=False,
                                                 preempt=False),
                        params).run(reqs)
    for a, b in zip(share.results, plain.results):
        np.testing.assert_array_equal(np.asarray(a.tokens),
                                      np.asarray(b.tokens),
                                      err_msg=f"rid {a.rid}")
    assert share.prefill_tokens_saved > 0
    assert 0.0 < share.prefix_hit_rate <= 1.0
    assert share.prompt_tokens == plain.prompt_tokens
    assert plain.prefill_tokens_saved == 0 and plain.prefix_hit_rate == 0.0
    # at least one join saved tokens on a request-level counter too
    assert sum(r.prefill_tokens_saved for r in share.results) \
        == share.prefill_tokens_saved


def test_preemption_requeue_parity(smollm):
    """A page pool too small for every admitted context forces mid-decode
    preemption: the victim's generated tokens fold into its prompt, it
    re-queues, and its final stream is STILL bit-identical to the
    ample-pool engine — with the prefix cache restoring the requeue, and
    without it (full recompute)."""
    cfg, params = smollm
    reqs = batch_trace(3, seed=5, vocab_size=cfg.vocab_size, prompt_len=6,
                       max_new_tokens=14)
    ample = ServeEngine(cfg, dataclasses.replace(ECFG, prefix_cache=False,
                                                 preempt=False),
                        params).run(reqs)
    # 2 scratch + 6 usable pages; each context needs ceil((6+14)/4) = 5
    tight = dataclasses.replace(ECFG, n_pages=2 + 6, preempt=True)
    for prefix in (True, False):
        rep = ServeEngine(cfg, dataclasses.replace(tight,
                                                   prefix_cache=prefix),
                          params).run(reqs)
        assert rep.n_preemptions > 0
        for a, b in zip(rep.results, ample.results):
            np.testing.assert_array_equal(
                np.asarray(a.tokens), np.asarray(b.tokens),
                err_msg=f"rid {a.rid} prefix={prefix}")
        assert sum(r.n_preemptions for r in rep.results) == rep.n_preemptions
        if prefix:
            # the requeue found its own pages in the cache
            assert rep.prefill_tokens_saved > 0


def test_scheduler_skip_ahead(smollm):
    """Head-of-line fix: when the queue head cannot get pages, a bounded
    skip-ahead admits smaller requests behind it; with max_skip=0 the old
    strict-FIFO stall is preserved, and admitted order stays FIFO among
    the requests that fit."""
    from repro.serving import RequestQueue, Scheduler
    cfg, _ = smollm

    def mk_reqs():
        return [
            Request(rid=0, prompt=np.zeros(13, np.int32), max_new_tokens=8),
            Request(rid=1, prompt=np.zeros(5, np.int32), max_new_tokens=4),
            Request(rid=2, prompt=np.zeros(5, np.int32), max_new_tokens=4),
        ]

    def mk_kv():
        # 4 usable pages; rid 0 needs 5 (13 + 8 - 1 -> 20 tokens), rids
        # 1/2 need 2 each
        return PagedKVCache(cfg, n_slots=2, page_size=4, max_len=32,
                            n_pages=2 + 4)

    sched = Scheduler(2, mk_kv(), max_skip=1)
    joins = sched.poll(RequestQueue(mk_reqs()), 0)
    assert [j[1].rid for j in joins] == [1, 2]      # FIFO among admissible

    strict = Scheduler(2, mk_kv(), max_skip=0)
    assert strict.poll(RequestQueue(mk_reqs()), 0) == []

    # when the head fits, ordering is plain FIFO regardless of max_skip
    fifo = Scheduler(2, mk_kv(), max_skip=3)
    queue = RequestQueue(mk_reqs()[1:])
    assert [j[1].rid for j in fifo.poll(queue, 0)] == [1, 2]


def test_paged_kv_prefix_sharing_unit(smollm):
    """admit_with_prefix maps cached full pages read-only (refcounted),
    emits a copy-on-write spec at partial-page boundaries, and trie-held
    pages survive release until evicted."""
    cfg, _ = smollm
    kv = PagedKVCache(cfg, n_slots=2, page_size=4, max_len=32, n_pages=12)
    tokens = np.arange(12, dtype=np.int32)          # 3 full pages
    m, copy = kv.admit_with_prefix(0, tokens, 12)
    assert m == 0 and copy is None                  # cold cache
    kv.register_prefix(0, tokens)                   # index pages 0/1/2
    p0, p1, p2 = (int(kv.tables[0, j]) for j in range(3))
    assert kv.refcount[p0] == 2 and kv.refcount[p2] == 2   # slot + trie
    kv.release(0)
    assert kv.refcount[p0] == 1 and kv.refcount[p2] == 1   # trie keeps them

    # 11-token prompt sharing the head: 2 full pages restored read-only,
    # then rows 8/9 of the cached third page via copy-on-write (the match
    # is capped at L-1 = 10, so at most 2 of page 2's rows can match)
    m, copy = kv.admit_with_prefix(1, tokens[:11], 11)
    assert m == 10                                  # 8 full + 2 CoW rows
    assert copy is not None and copy.n_rows == 2
    assert copy.src_page == p2
    assert copy.dst_page == kv.tables[1, 2]
    assert kv.tables[1, 0] == p0 and kv.tables[1, 1] == p1
    assert kv.refcount[p0] == 2                     # shared read-only again
    assert kv.refcount[p2] == 2                     # trie + pending copy
    kv.copy_done(copy.src_page)
    assert kv.refcount[p2] == 1
    kv.release(1)

    # diverging prompt: only the common full pages match, no CoW
    other = np.concatenate([tokens[:8], np.full(6, 77, np.int32)])
    assert kv.can_admit_with_prefix(other, 14)
    m2, copy2 = kv.admit_with_prefix(1, other, 14)
    assert m2 == 8 and copy2 is None
    kv.release(1)

    # eviction reclaims trie-only pages when the pool runs dry
    kv2 = PagedKVCache(cfg, n_slots=1, page_size=4, max_len=16, n_pages=5)
    kv2.admit_with_prefix(0, np.arange(8, dtype=np.int32), 8)
    kv2.register_prefix(0, np.arange(8, dtype=np.int32))
    kv2.release(0)
    assert kv2.n_free == 2 and kv2.n_evictable() == 2
    kv2.admit(0, 16)                                # needs all 4 -> evicts
    assert kv2.n_free == 0 and kv2.n_evictable() == 0


def test_paged_kv_manager_invariants(smollm):
    cfg, _ = smollm
    kv = PagedKVCache(cfg, n_slots=2, page_size=4, max_len=32, n_pages=8)
    assert kv.n_free == 6                       # pages 0/1 are slot scratch
    pages = kv.admit(0, 9)                      # 3 pages
    assert len(pages) == 3 and all(p >= 2 for p in pages)
    assert (kv.tables[0, :3] == pages).all()
    assert (kv.tables[0, 3:] == 0).all()        # tail parked on scratch 0
    assert (kv.tables[1] == 1).all()
    with pytest.raises(ValueError):
        kv.admit(0, 4)                          # double-admit
    assert not kv.can_admit(4 * 4)              # 4 pages > 3 free
    kv.release(0)
    assert kv.n_free == 6 and (kv.tables[0] == 0).all()
    rows = kv.inject_rows(1, bucket_len=8, n_valid=5)
    kv.admit(1, 5)
    rows = kv.inject_rows(1, bucket_len=8, n_valid=5)
    assert (rows[5:] == kv.n_pages * kv.page_size).all()   # pad rows dropped
    assert len(set(rows[:5].tolist())) == 5

    # SSM families build state-slot pools instead of page tables: the
    # manager accepts them but reports the block tables as inactive (the
    # host tier has no page pool to ride)
    kv_ssm = PagedKVCache(get_arch("mamba2-370m").smoke, n_slots=2,
                          page_size=4, max_len=32)
    assert not kv_ssm.tables_active
    assert kv.tables_active


def test_energy_aware_admission(smollm):
    """The hook admits while predicted draw fits the budget, under the cap
    in force."""
    from repro.core import PowerCappedDevice, TPU_V5E
    from repro.launch.serve import decode_workload
    cfg, _ = smollm

    class Backend:
        cap = 1.0

        def current_cap(self):
            return self.cap

    device = PowerCappedDevice(TPU_V5E)
    backend = Backend()
    p1 = device.estimate(decode_workload(cfg, 1), 1.0).power_w
    hook = EnergyAwareAdmission(device, lambda n: decode_workload(cfg, n),
                                budget_w=p1 + 1e-6, backend=backend)
    req = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=4)
    assert hook(req, 1)
    assert not hook(req, 10**6)                 # far past the budget
    backend.cap = 0.3                           # deep cap -> lower draw
    assert hook(req, 1)


def test_decode_loop_nongreedy_deterministic(smollm):
    """Non-greedy fused decode: same key -> same stream, different key ->
    different stream (CLI --temperature/--sample-seed path)."""
    cfg, params = smollm
    step_cfg = StepConfig(remat="none")
    from repro.runtime.steps import make_prefill_step
    prefill = jax.jit(make_prefill_step(cfg, step_cfg, max_len=32))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    last_logits, cache = prefill(params, {"inputs": prompts})
    tok0 = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
    loop = jax.jit(make_decode_loop(cfg, step_cfg, n_tokens=8, greedy=False,
                                    temperature=0.9))
    k1, k2 = jax.random.PRNGKey(3), jax.random.PRNGKey(4)
    a, _ = loop(params, cache, tok0, k1)
    b, _ = loop(params, cache, tok0, k1)
    c, _ = loop(params, cache, tok0, k2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.any(np.asarray(a) != np.asarray(c))


def test_decode_loop_nongreedy_multicodebook():
    """The n_cb (musicgen) path: non-greedy sampling stays deterministic
    per codebook under a fixed key."""
    cfg = get_arch("musicgen-medium").smoke
    step_cfg = StepConfig(remat="none")
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    from repro.runtime.steps import make_prefill_step
    prefill = jax.jit(make_prefill_step(cfg, step_cfg, max_len=24))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (2, 8, cfg.n_codebooks), 0, cfg.vocab_size)
    last_logits, cache = prefill(params, {"inputs": prompts})
    tok0 = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
    assert tok0.shape == (2, 1, cfg.n_codebooks)
    loop = jax.jit(make_decode_loop(cfg, step_cfg, n_tokens=5, greedy=False,
                                    temperature=1.0))
    key = jax.random.PRNGKey(9)
    a, _ = loop(params, cache, tok0, key)
    b, _ = loop(params, cache, tok0, key)
    assert a.shape == (2, 5, cfg.n_codebooks)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
