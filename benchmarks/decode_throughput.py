"""Decode fast-path throughput: per-token host loop vs fused lax.scan.

The serving question behind FROST's J/token metric: decode is memory-bound,
so its energy per token is nearly cap-invariant — but its *throughput* is
host-limited when every token pays a Python dispatch + device sync.  This
benchmark measures that gap on the smoke config across KV-cache lengths:

  a. per-token  — jitted ``make_serve_step`` driven from a Python loop with
                  a host sync per token (the pre-fast-path serving cadence),
  b. fused      — ``make_decode_loop``: the same sampling + cache update
                  inside ONE jitted ``lax.scan`` per block.

J/token comes from the calibrated device model at 100% TDP and at a deep
cap, so the artifact records how throughput gains compound with capping
(tok/s up at constant J/token => W down, the paper's serving trade).

Emits ``decode.*`` CSV lines and a JSON artifact (via benchmarks.run) so
future PRs have a perf trajectory.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import PowerCappedDevice, TPU_V5E, WorkloadProfile
from repro.models import transformer as tfm
from repro.runtime.steps import (StepConfig, make_decode_loop,
                                 make_prefill_step, make_serve_step)

DEEP_CAP = 0.5                      # the near-free decode cap (paper Sec IV)


def _j_per_token(cfg, requests: int, cap: float) -> float:
    """Analytic J/token for the decode roofline under ``cap``."""
    p = float(cfg.param_count())
    wl = WorkloadProfile(name=f"{cfg.name}-decode",
                         flops_per_step=2.0 * p * requests,
                         hbm_bytes_per_step=2.0 * p,
                         samples_per_step=requests)
    est = PowerCappedDevice(TPU_V5E).estimate(wl, cap)
    return est.energy_j / requests


def bench_one(cfg, *, cache_len: int, requests: int, prompt_len: int,
              gen: int, seed: int = 0) -> dict:
    step_cfg = StepConfig(remat="none")
    params, _ = tfm.init_lm(jax.random.PRNGKey(seed), cfg)
    prefill = jax.jit(make_prefill_step(cfg, step_cfg, max_len=cache_len))
    serve = jax.jit(make_serve_step(cfg, step_cfg))
    # no cache donation here: both paths restart from the same prefill state
    loop = jax.jit(make_decode_loop(cfg, step_cfg, n_tokens=gen))

    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                 (requests, prompt_len), 0, cfg.vocab_size)
    if cfg.n_codebooks:
        prompts = jax.random.randint(
            jax.random.PRNGKey(seed + 1),
            (requests, prompt_len, cfg.n_codebooks), 0, cfg.vocab_size)
    last_logits, cache = prefill(params, {"inputs": prompts})
    first = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    tok0 = first[:, None]
    jax.block_until_ready(cache)

    # -- a. per-token host loop (sync per token: the old serving cadence) ---
    def run_per_token():
        tok, c = tok0, cache
        for _ in range(gen):
            nxt, c = serve(params, c, tok)
            nxt = jax.block_until_ready(nxt)     # host sync per token
            tok = nxt[:, None]
        return tok

    def best_of(fn, reps: int = 3) -> float:
        """Min over repeats — the noise floor of a shared CI box."""
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return min(times)

    run_per_token()                              # warm the jit
    t_per_token = best_of(run_per_token)

    # -- b. fused lax.scan block ------------------------------------------
    def run_fused():
        jax.block_until_ready(loop(params, cache, tok0)[0])

    run_fused()                                  # warm the jit
    t_fused = best_of(run_fused)

    n_tok = gen * requests
    return {
        "cache_len": cache_len,
        "requests": requests,
        "gen": gen,
        "per_token_tok_per_s": n_tok / max(t_per_token, 1e-9),
        "fused_tok_per_s": n_tok / max(t_fused, 1e-9),
        "speedup": t_per_token / max(t_fused, 1e-9),
        "j_per_token_cap100": _j_per_token(cfg, requests, 1.0),
        "j_per_token_deep_cap": _j_per_token(cfg, requests, DEEP_CAP),
    }


def run(quick: bool = False) -> dict:
    spec = get_arch("smollm-135m")
    # the benchmark isolates HOST-LOOP overhead, so the model is shrunk below
    # even the smoke config: per-step device compute must not drown the
    # per-token dispatch+sync cost this benchmark exists to measure
    cfg = dataclasses.replace(spec.smoke, d_model=64, d_ff=128, head_dim=16,
                              name=spec.smoke.name + "-bench")
    cache_lens = [64, 128] if quick else [64, 128, 256]
    gen = 32 if quick else 96
    rows = [bench_one(cfg, cache_len=c, requests=2, prompt_len=16, gen=gen)
            for c in cache_lens]
    head = rows[-1]                  # largest cache = the honest serving point
    return {
        "arch": cfg.name,
        "deep_cap": DEEP_CAP,
        "rows": rows,
        "tok_per_s": head["fused_tok_per_s"],
        "per_token_tok_per_s": head["per_token_tok_per_s"],
        "speedup": head["speedup"],
        "j_per_token_cap100": head["j_per_token_cap100"],
        "j_per_token_deep_cap": head["j_per_token_deep_cap"],
    }


def main(quick: bool = False) -> dict:
    res = run(quick=quick)
    for r in res["rows"]:
        print(f"decode.tok_per_s,{r['fused_tok_per_s']:.1f},"
              f"fused lax.scan loop (C={r['cache_len']}, B={r['requests']})")
        print(f"decode.per_token_tok_per_s,{r['per_token_tok_per_s']:.1f},"
              f"per-token host loop (C={r['cache_len']})")
        print(f"decode.speedup,{r['speedup']:.2f}x,"
              f"fused vs per-token (C={r['cache_len']})")
    print(f"decode.j_per_token,{res['j_per_token_cap100']:.3g},"
          f"analytic @100% TDP ({res['j_per_token_deep_cap']:.3g} "
          f"@{DEEP_CAP:.0%} cap — near-free: decode is memory-bound)")
    return res


if __name__ == "__main__":
    main()
