"""Prefix-sharing paged KV cache vs the no-sharing engine.

FROST caps power around a fixed workload; the prefix cache shrinks the
workload itself — every prompt token restored from a cached prefix is
prefill compute (and its joules) never drawn, the demand-side complement
to supply-side capping.  Real serving traffic overwhelmingly shares prompt
heads (system prompts, few-shot headers), which is exactly the regime this
benchmark constructs.

Both engines run the SAME shared-prefix Poisson trace on the same shrunk
model and the same deliberately tight page pool:

  a. share   — ``EngineConfig(prefix_cache=True, preempt=True)``: cached
               prefixes map onto shared read-only pages (copy-on-write at
               partial-page boundaries), only uncached suffixes prefill
               (chunked, through the paged verify sweep), and page
               pressure preempts/re-queues instead of stalling admission.
  b. plain   — ``prefix_cache=False, preempt=False``: every prompt
               prefills in full and admission reserves the whole context
               (the PR-3/4 engine).

Energy is modelled: the analytic device at 100% TDP and the deep cap for
decode chunks at live occupancy, plus a per-token prefill charge for every
prompt token actually computed — sharing wins on J/token by computing
fewer of them, and on p50 latency because shared pages admit more
concurrency from the same pool.

This benchmark doubles as the CI correctness gate for the whole subsystem:
it RAISES if the per-request greedy token streams differ between the two
engines (prefix sharing and preemption must be invisible in the output),
or if the shared-prefix fixture produces a zero hit rate.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs import get_arch
from repro.core import PowerCappedDevice, TPU_V5E
from repro.launch.serve import decode_workload
from repro.models import transformer as tfm
from repro.serving import EngineConfig, ServeEngine, poisson_trace

import jax

DEEP_CAP = 0.5


def _energy(device, cfg, n_active: int, n_steps: int, cap: float) -> float:
    est = device.estimate(decode_workload(cfg, n_active), cap)
    return est.energy_j * n_steps


def run_one(cfg, device, trace, ecfg, *, seed: int = 0) -> dict:
    params, _ = tfm.init_lm(jax.random.PRNGKey(seed), cfg)
    energy = {1.0: 0.0, DEEP_CAP: 0.0}

    def on_chunk(stats):
        for cap in energy:
            energy[cap] += _energy(device, cfg, stats.n_active,
                                   ecfg.decode_chunk, cap)
        return _energy(device, cfg, stats.n_active, ecfg.decode_chunk, 1.0)

    rep = ServeEngine(cfg, ecfg, params, on_chunk=on_chunk).run(trace)
    lat = rep.latency_percentiles((50, 95))
    # prompt tokens actually prefilled (cache restores are free); priced at
    # the analytic one-sequence sweep cost — same model both engines
    prefilled = rep.prompt_tokens - rep.prefill_tokens_saved
    e_tok = {cap: device.estimate(decode_workload(cfg, 1), cap).energy_j
             for cap in energy}
    out = {
        "tok_per_s": rep.tok_per_s,
        "useful_tokens": rep.tokens_kept,
        "prompt_tokens": rep.prompt_tokens,
        "prefill_tokens_computed": prefilled,
        "prefill_tokens_saved": rep.prefill_tokens_saved,
        "prefix_hit_rate": rep.prefix_hit_rate,
        "n_preemptions": rep.n_preemptions,
        "occupancy": rep.occupancy,
        "p50_latency_steps": lat[50],
        "p95_latency_steps": lat[95],
        "tokens": [list(r.tokens) for r in rep.results],
    }
    for cap, tag in ((1.0, "cap100"), (DEEP_CAP, "deep_cap")):
        total = energy[cap] + e_tok[cap] * prefilled
        out[f"j_per_token_{tag}"] = total / max(rep.tokens_kept, 1)
        out[f"prefill_j_avoided_{tag}"] = \
            e_tok[cap] * rep.prefill_tokens_saved
    return out


def run(quick: bool = False) -> dict:
    spec = get_arch("smollm-135m")
    # shrunk below the smoke config: the benchmark contrasts how much
    # PREFILL each engine performs and how admission behaves under page
    # pressure, so per-step device compute must not drown either
    cfg = dataclasses.replace(spec.smoke, d_model=64, d_ff=128, head_dim=16,
                              name=spec.smoke.name + "-bench")
    device = PowerCappedDevice(TPU_V5E)
    n_req = 8 if quick else 16
    n_slots, chunk, page_size = 4, 8, 8
    shared, suffix, gen = 44, (4, 12), (6, 16)   # 44 % 8 != 0: CoW exercised
    max_len = shared + suffix[1] + gen[1]
    # tight pool (~2 full contexts): the plain engine must reserve whole
    # contexts and stalls the queue; sharing fits more concurrent requests
    # into the same pages and preempts/re-queues when decode outgrows them
    n_pages = n_slots + 2 * -(-max_len // page_size)
    trace = poisson_trace(n_req, rate_per_step=0.5, seed=23,
                          vocab_size=cfg.vocab_size, prompt_len=suffix,
                          max_new_tokens=gen, shared_prefix_len=shared,
                          prompt_pools=1)
    base = EngineConfig(n_slots=n_slots, page_size=page_size, max_len=max_len,
                        decode_chunk=chunk, n_pages=n_pages)
    eng = run_one(cfg, device, trace,
                  dataclasses.replace(base, prefix_cache=True, preempt=True))
    pla = run_one(cfg, device, trace,
                  dataclasses.replace(base, prefix_cache=False, preempt=False))
    # correctness gates (CI smoke): sharing/preemption must be invisible in
    # the greedy streams, and the shared-prefix fixture must actually hit
    for i, (a, b) in enumerate(zip(eng.pop("tokens"), pla.pop("tokens"))):
        if a != b:
            raise RuntimeError(
                f"prefix-sharing engine diverged from the plain engine on "
                f"rid {i}: {a[:8]} vs {b[:8]} — sharing/preemption broke "
                "greedy exactness")
    if eng["prefix_hit_rate"] <= 0.0:
        raise RuntimeError("prefix_hit_rate == 0 on the shared-prefix "
                           "fixture — the cache never matched")
    return {
        "arch": cfg.name,
        "n_requests": n_req,
        "n_slots": n_slots,
        "n_pages": n_pages,
        "shared_prefix_len": shared,
        "deep_cap": DEEP_CAP,
        "share": eng,
        "plain": pla,
        "tok_per_s": eng["tok_per_s"],
        "prefix_hit_rate": eng["prefix_hit_rate"],
        "prefill_tokens_saved": eng["prefill_tokens_saved"],
        "n_preemptions": eng["n_preemptions"],
        "j_per_token_ratio": pla["j_per_token_cap100"]
        / max(eng["j_per_token_cap100"], 1e-12),
        "p50_latency_ratio": pla["p50_latency_steps"]
        / max(eng["p50_latency_steps"], 1e-9),
    }


def main(quick: bool = False) -> dict:
    res = run(quick=quick)
    for name in ("share", "plain"):
        r = res[name]
        print(f"prefix.{name}_j_per_token,{r['j_per_token_cap100']:.3g},"
              f"analytic @100% TDP incl. prefill "
              f"({r['j_per_token_deep_cap']:.3g} @{res['deep_cap']:.0%} cap)")
        print(f"prefix.{name}_p50_latency,{r['p50_latency_steps']:.0f},"
              f"steps (p95 {r['p95_latency_steps']:.0f}; occupancy "
              f"{r['occupancy']:.0%})")
        print(f"prefix.{name}_prefill_tokens,{r['prefill_tokens_computed']},"
              f"computed of {r['prompt_tokens']} prompt tokens "
              f"({r['prefill_tokens_saved']} restored from cache)")
    print(f"prefix.hit_rate,{res['prefix_hit_rate']:.3f},"
          f"prompt tokens restored instead of prefilled (must be > 0)")
    print(f"prefix.n_preemptions,{res['n_preemptions']},"
          f"slots evicted + re-queued under the tight page pool")
    print(f"prefix.j_per_token_ratio,{res['j_per_token_ratio']:.2f}x,"
          f"plain / share — prefill compute the cache eliminated")
    print(f"prefix.p50_latency_ratio,{res['p50_latency_ratio']:.2f}x,"
          f"plain / share under the same tight pool (shared pages admit "
          "more concurrency)")
    return res


if __name__ == "__main__":
    main()
