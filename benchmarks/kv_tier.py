"""Two-tier KV hierarchy (int8 pages + host page-out) vs evict-and-recompute.

FROST treats energy as the objective; PR 5's prefix cache shrinks prefill
compute, and this PR shrinks the *memory* that caching needs: int8 pages
with per-row fp32 scales store ~0.6x the bytes of a bf16 page (dequant
fused into the split-KV sweeps), and cold prefix pages demote to a
host-memory pool instead of being dropped — paged back in on the next
prefix hit for a modelled transfer charge instead of a re-prefill.

Three engines run the SAME shared-prefix Poisson trace on the same shrunk
model; the baseline's device pool is deliberately tight (~2 contexts):

  a. evict — bf16 pages, no host tier, ``P0`` device pages: cold pages are
             dropped and their tokens recomputed on the next prefix hit
             (the PR 5 engine).
  b. tier  — int8 pages at DEVICE BYTE PARITY with (a) (same HBM bytes buy
             ~1.6x the pages) plus a host pool sized so the logical pool
             is >= 4x the baseline's; the demote-vs-evict rule is priced
             from the analytic device.
  c. tier_bf16 — bf16 pages + host tier on the SAME ``P0`` device pages as
             (a): isolates page-out correctness from quantization.

Energy is modelled exactly as in benchmarks/prefix_cache.py (analytic
device, decode chunks at live occupancy + per-token prefill charge) with
one addition: the tier engines' ledgers include the charged D2H/H2D
transfer joules, so the J/token comparison is honest about what paging
costs.

CI correctness gates — this benchmark RAISES if:
  * the fused int8 decode sweep diverges from the quantized reference
    oracle (kernel-level check, both decode and paged families),
  * page-out loses a committed token: (c)'s greedy streams must be
    bit-identical to (a)'s,
  * the tier engine's logical pool is < 4x the baseline's device pool, or
    its prefix hit rate / preemption count / J-per-token (transfer
    included) regress against evict-and-recompute.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs import get_arch
from repro.core import PowerCappedDevice, TPU_V5E
from repro.kernels import ops, ref
from repro.launch.serve import decode_workload
from repro.models import transformer as tfm
from repro.quant import quantize_int8_rows
from repro.serving import EngineConfig, ServeEngine, poisson_trace

import jax
import jax.numpy as jnp

DEEP_CAP = 0.5


def _energy(device, cfg, n_active: int, n_steps: int, cap: float) -> float:
    est = device.estimate(decode_workload(cfg, n_active), cap)
    return est.energy_j * n_steps


def check_int8_oracle(tol: float = 5e-5) -> float:
    """Kernel-level gate: the fused-dequant decode sweeps must match the
    quantized reference oracle (fp32 dequant outside the kernel) on random
    int8 pools.  Returns the max abs error across both cache layouts."""
    key = jax.random.PRNGKey(7)
    B, Hq, Hkv, hd, C = 2, 4, 2, 16, 64
    ks = [jax.random.normal(k, s, jnp.float32) for k, s in zip(
        jax.random.split(key, 3),
        [(B, 1, Hq, hd), (B, C, Hkv, hd), (B, C, Hkv, hd)])]
    q, k_f, v_f = ks
    kq, kscale = quantize_int8_rows(k_f)
    vq, vscale = quantize_int8_rows(v_f)
    pos = jnp.asarray(C - 3, jnp.int32)      # ring pos is a scalar
    k_pos = ops.ring_positions(pos, C)
    scale = 1.0 / np.sqrt(hd)
    got = ops.decode_attention(q, kq, vq, pos, scale=scale,
                               k_scale=kscale, v_scale=vscale)
    want = ref.decode_attention_ref(q, kq, vq, k_pos, pos, scale=scale,
                                    k_scale=kscale, v_scale=vscale)
    err = float(jnp.max(jnp.abs(got - want)))

    P, ps, nb = 6, 8, 3
    kp = jax.random.normal(jax.random.fold_in(key, 4), (P, ps, Hkv, hd),
                           jnp.float32)
    vp = jax.random.normal(jax.random.fold_in(key, 5), (P, ps, Hkv, hd),
                           jnp.float32)
    kpq, kps = quantize_int8_rows(kp)
    vpq, vps = quantize_int8_rows(vp)
    bt = jnp.array([[0, 2, 4], [1, 3, 5]], jnp.int32)
    ppos = jnp.array([nb * ps - 2, ps + 3], jnp.int32)
    got = ops.paged_decode_attention(q, kpq, vpq, bt, ppos, scale=scale,
                                     k_scale=kps, v_scale=vps)
    want = ref.paged_decode_attention_ref(q, kpq, vpq, bt, ppos, scale=scale,
                                          k_scale=kps, v_scale=vps)
    err = max(err, float(jnp.max(jnp.abs(got - want))))
    if not err <= tol:
        raise RuntimeError(
            f"fused int8 decode diverged from the quantized ref oracle "
            f"(max abs err {err:.3e} > {tol:.0e}) — the dequant fusion is "
            "mis-scaling rows")
    return err


def run_one(cfg, device, trace, ecfg, *, seed: int = 0) -> dict:
    params, _ = tfm.init_lm(jax.random.PRNGKey(seed), cfg)
    energy = {1.0: 0.0, DEEP_CAP: 0.0}

    def on_chunk(stats):
        for cap in energy:
            energy[cap] += _energy(device, cfg, stats.n_active,
                                   ecfg.decode_chunk, cap)
        return _energy(device, cfg, stats.n_active, ecfg.decode_chunk, 1.0)

    eng = ServeEngine(cfg, ecfg, params, on_chunk=on_chunk)
    rep = eng.run(trace)
    prefilled = rep.prompt_tokens - rep.prefill_tokens_saved
    e_tok = {cap: device.estimate(decode_workload(cfg, 1), cap).energy_j
             for cap in energy}
    out = {
        "tok_per_s": rep.tok_per_s,
        "useful_tokens": rep.tokens_kept,
        "prompt_tokens": rep.prompt_tokens,
        "prefill_tokens_computed": prefilled,
        "prefill_tokens_saved": rep.prefill_tokens_saved,
        "prefix_hit_rate": rep.prefix_hit_rate,
        "n_preemptions": rep.n_preemptions,
        "n_demotions": rep.n_demotions,
        "n_promotions": rep.n_promotions,
        "transfer_j": rep.transfer_j,
        "host_used": eng.kv.n_host_used(),
        "occupancy": rep.occupancy,
        "tokens": [list(r.tokens) for r in rep.results],
    }
    for cap, tag in ((1.0, "cap100"), (DEEP_CAP, "deep_cap")):
        # decode chunks + prefill actually computed + charged transfers —
        # the tier pays for its paging inside the figure it is judged on
        total = energy[cap] + e_tok[cap] * prefilled + rep.transfer_j
        out[f"j_per_token_{tag}"] = total / max(rep.tokens_kept, 1)
    return out


def run(quick: bool = False) -> dict:
    oracle_err = check_int8_oracle()
    spec = get_arch("smollm-135m")
    cfg = dataclasses.replace(spec.smoke, d_model=64, d_ff=128, head_dim=16,
                              name=spec.smoke.name + "-bench")
    device = PowerCappedDevice(TPU_V5E)
    n_req = 8 if quick else 16
    n_slots, chunk, page_size = 4, 8, 8
    shared, suffix, gen = 44, (4, 12), (6, 16)
    max_len = shared + suffix[1] + gen[1]
    # tight baseline pool (~1 full context + slack): decode growth keeps
    # evicting the trie's cold pages, so evict-and-recompute loses cached
    # prefixes exactly when the next request wants them
    p0 = n_slots + -(-max_len // page_size) + 2
    # device byte parity: one int8 page (hd + 4 bytes/row/head) costs
    # ~0.625x a bf16 page (2*hd), so the same HBM budget buys more pages
    hd = cfg.head_dim
    int8_pages = int(p0 * (2 * hd) / (hd + 4))
    host_pages = 4 * p0 - int8_pages        # logical pool >= 4x baseline
    recompute_j = device.estimate(decode_workload(cfg, 1), 1.0).energy_j
    trace = poisson_trace(n_req, rate_per_step=0.5, seed=23,
                          vocab_size=cfg.vocab_size, prompt_len=suffix,
                          max_new_tokens=gen, shared_prefix_len=shared,
                          prompt_pools=1)
    base = EngineConfig(n_slots=n_slots, page_size=page_size, max_len=max_len,
                        decode_chunk=chunk, n_pages=p0)
    evict = run_one(cfg, device, trace, base)
    tier = run_one(cfg, device, trace, dataclasses.replace(
        base, n_pages=int8_pages, kv_dtype="int8", host_tier=True,
        host_pages=host_pages, recompute_j_per_token=recompute_j))
    tier_bf16 = run_one(cfg, device, trace, dataclasses.replace(
        base, host_tier=True, host_pages=host_pages,
        recompute_j_per_token=recompute_j))
    # the raw per-engine hit rate divides by prompt tokens INCLUDING requeue
    # re-joins, so an engine that preempts more inflates its own metric; the
    # offered load (the trace's prompt tokens, identical for every engine)
    # is the comparable denominator — requeue re-prefill counts against it
    offered = sum(r.prompt_len for r in trace)
    for r in (evict, tier, tier_bf16):
        r["effective_hit_rate"] = \
            1.0 - r["prefill_tokens_computed"] / max(offered, 1)

    # gate: paging out and back in must never lose a committed token —
    # (c) differs from (a) ONLY by the host tier, so greedy streams must
    # be bit-identical
    for i, (a, b) in enumerate(zip(evict["tokens"],
                                   tier_bf16.pop("tokens"))):
        if a != b:
            raise RuntimeError(
                f"host-tier engine diverged from the evict baseline on rid "
                f"{i}: {b[:8]} vs {a[:8]} — page-out lost or corrupted a "
                "committed token")
    evict.pop("tokens")
    tier.pop("tokens")

    logical_ratio = (int8_pages + host_pages) / p0
    if logical_ratio < 4.0:
        raise RuntimeError(f"logical pool ratio {logical_ratio:.2f} < 4x "
                           "the baseline device pool")
    if tier["effective_hit_rate"] < evict["effective_hit_rate"]:
        raise RuntimeError(
            f"tier effective hit rate {tier['effective_hit_rate']:.3f} "
            f"regressed below evict-and-recompute "
            f"{evict['effective_hit_rate']:.3f}")
    if tier["n_preemptions"] > evict["n_preemptions"]:
        raise RuntimeError(
            f"tier preempted {tier['n_preemptions']}x vs baseline "
            f"{evict['n_preemptions']}x — the bigger logical pool "
            "should shed page pressure")
    if tier["j_per_token_cap100"] >= evict["j_per_token_cap100"]:
        raise RuntimeError(
            f"tier J/token {tier['j_per_token_cap100']:.3g} (transfer "
            f"included) did not beat evict-and-recompute "
            f"{evict['j_per_token_cap100']:.3g}")
    return {
        "arch": cfg.name,
        "n_requests": n_req,
        "baseline_pages": p0,
        "tier_device_pages": int8_pages,
        "tier_host_pages": host_pages,
        "logical_pool_ratio": logical_ratio,
        "deep_cap": DEEP_CAP,
        "int8_oracle_max_err": oracle_err,
        "evict": evict,
        "tier": tier,
        "tier_bf16": tier_bf16,
        "offered_prompt_tokens": offered,
        "tok_per_s": tier["tok_per_s"],
        "prefix_hit_rate": tier["prefix_hit_rate"],
        "effective_hit_rate": tier["effective_hit_rate"],
        "n_preemptions": tier["n_preemptions"],
        "n_demotions": tier["n_demotions"],
        "n_promotions": tier["n_promotions"],
        "transfer_j": tier["transfer_j"],
        "j_per_token_ratio": evict["j_per_token_cap100"]
        / max(tier["j_per_token_cap100"], 1e-12),
    }


def main(quick: bool = False) -> dict:
    res = run(quick=quick)
    print(f"kvtier.int8_oracle_max_err,{res['int8_oracle_max_err']:.2e},"
          "fused-dequant sweep vs quantized ref oracle (gate)")
    print(f"kvtier.logical_pool_ratio,{res['logical_pool_ratio']:.2f}x,"
          f"{res['tier_device_pages']} int8 device pages (byte parity with "
          f"{res['baseline_pages']} bf16) + {res['tier_host_pages']} host")
    for name in ("evict", "tier", "tier_bf16"):
        r = res[name]
        print(f"kvtier.{name}_j_per_token,{r['j_per_token_cap100']:.3g},"
              f"analytic @100% TDP incl. prefill + transfer "
              f"({r['j_per_token_deep_cap']:.3g} @{res['deep_cap']:.0%} cap)")
        print(f"kvtier.{name}_hit_rate,{r['effective_hit_rate']:.3f},"
              f"of {res['offered_prompt_tokens']} offered prompt tokens "
              f"({r['prefill_tokens_computed']} prefilled incl. requeues); "
              f"{r['n_preemptions']} preemptions, {r['n_demotions']} paged "
              f"out / {r['n_promotions']} paged in")
    print(f"kvtier.transfer_j,{res['transfer_j']:.3g},"
          "modelled D2H+H2D joules charged into the tier's J/token")
    print(f"kvtier.j_per_token_ratio,{res['j_per_token_ratio']:.2f}x,"
          "evict-and-recompute / two-tier (transfer included)")
    return res


if __name__ == "__main__":
    main()
