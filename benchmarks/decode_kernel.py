"""Decode-attention kernel microbench: two-stage split-KV scaling.

Sweeps batch x KV depth x ``kv_splits`` over the decode sweep and reports
**modelled** tok/s from the TPU_V5E occupancy roofline (same analytic
device model every J/token figure in this repo uses — the CPU stand-in
cannot measure TPU grid occupancy, and a 1-core host would report the
opposite sign).  The model charges stage 1 with the KV stream at the
bandwidth the occupied fraction of the chip can draw
(``util = min(1, grid_cells / n_exec)``: an underfilled grid leaves
memory controllers idle, the exact deficit splitting repairs), plus a
per-kernel launch cost and — for two-stage points — the stage-2 merge
traffic, so large split counts pay their overhead and cannot win for
free.

Measured numbers ride along: wall-clock of the jnp sweep (informational;
host-bound) and **exactness on real arrays** (two-stage vs single-stage,
max |err| and greedy-argmax agreement), which gate the artifact.

RAISES (CI smoke runs this via ``benchmarks.run --only kernel``):
  * exactness: max |err| beyond fp32 tolerance or any greedy argmax flip,
  * shallow regression: modelled tok/s at the auto-chosen split count
    below single-split at ANY point,
  * scaling: < ``MIN_DEEP_SPEEDUP``x modelled speedup vs ``kv_splits=1``
    at the deepest KV length (lowest-batch row).

Emits ``kernel.*`` CSV lines and a git-SHA-stamped ``BENCH_kernel.json``
trajectory artifact (via benchmarks.run).

``main_mla`` (the ``mla`` job in benchmarks.run, also in the CI bench
smoke) runs the same harness over the compressed-latent MLA paged sweep:
the occupancy model at the MLA grid shape (128 q heads sharing ONE latent
row, so cells = batch x splits), real-array exactness of the jnp and
interpret-mode Pallas backends against the ``ref.mla_decode_paged_ref`` /
``ref.mla_decode_split_ref`` oracles (RAISES on drift), and the
KV-bytes/token compression ratio vs a GQA-equivalent layout — the ratio
every host-tier transfer joule scales by.  Emits ``BENCH_mla.json``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TPU_V5E
from repro.kernels import ops
from repro.kernels.ops import choose_kv_splits

# modelled chip: executors that can host independent (b, h, split) grid
# cells concurrently.  8 = one v5e chip's worth of independent sweep lanes.
N_EXEC = 8
# per-stage dispatch cost: both stages live in ONE jitted executable (no
# host round-trip), so this is XLA op scheduling overhead, not a launch
LAUNCH_S = 5e-7
MIN_DEEP_SPEEDUP = 1.3            # acceptance floor at the deepest KV point
EXACT_TOL = 2e-5                  # fp32 reassociation budget for real arrays

# sweep geometry (GQA, bf16 cache — the serving default)
HQ, HKV, D, DV = 4, 2, 64, 64
KV_BYTES = 2                      # bf16 storage
BLOCK = 256                       # decode_k_chunk: keys per grid step


def model_sweep_time(batch: int, kv_len: int, n_splits: int) -> float:
    """Roofline time for one decode sweep at this operating point."""
    n_blocks = -(-kv_len // BLOCK)
    s = max(1, min(n_splits, n_blocks))
    cells = batch * HQ * s
    util = min(1.0, cells / N_EXEC)
    kv_bytes = batch * kv_len * HKV * (D + DV) * KV_BYTES
    flops = 2.0 * batch * HQ * kv_len * (D + DV)
    t1 = max(kv_bytes / (TPU_V5E.hbm_bw * util),
             flops / (TPU_V5E.peak_flops * TPU_V5E.matmul_efficiency * util))
    t = t1 + LAUNCH_S
    if s > 1:
        # stage 2: read S partials + LSE per (b, h) row, write one row out
        merge_bytes = batch * HQ * (s * (DV + 1) + DV) * 4
        t += merge_bytes / TPU_V5E.hbm_bw + LAUNCH_S
    return t


def modelled_tok_per_s(batch: int, kv_len: int, n_splits: int) -> float:
    return batch / model_sweep_time(batch, kv_len, n_splits)


def _measure_exactness() -> dict:
    """Real-array parity: two-stage jnp and Pallas-interpret sweeps vs the
    single-stage path, plus greedy argmax through a projection head."""
    rng = np.random.default_rng(0)
    B, C = 2, 512
    q = jnp.asarray(rng.standard_normal((B, 1, HQ, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, C, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, C, HKV, DV)), jnp.float32)
    head = jnp.asarray(rng.standard_normal((HQ * DV, 128)), jnp.float32)
    pos = jnp.int32(C + 37)                        # wrapped ring
    k_pos = ops.ring_positions(pos, C)

    single = ops.decode_attention_jnp(q, k, v, k_pos, pos)
    ref_arg = jnp.argmax(single.reshape(B, -1) @ head, axis=-1)
    max_err, argmax_ok = 0.0, True
    for s in (2, 4, 8):
        two = ops.decode_attention_jnp(q, k, v, k_pos, pos, n_splits=s)
        max_err = max(max_err, float(jnp.max(jnp.abs(single - two))))
        argmax_ok &= bool(jnp.all(
            jnp.argmax(two.reshape(B, -1) @ head, axis=-1) == ref_arg))
    # one Pallas-interpret point (the kernel the model stands in for)
    from repro.kernels import decode_attention as da
    p1 = da.decode_attention_pallas(q, k, v, pos, block_k=64, interpret=True)
    p4 = da.decode_attention_pallas(q, k, v, pos, block_k=64, n_splits=4,
                                    interpret=True)
    max_err = max(max_err, float(jnp.max(jnp.abs(p1 - p4))))
    argmax_ok &= bool(jnp.all(
        jnp.argmax(p4.reshape(B, -1) @ head, axis=-1) == ref_arg))
    return {"max_exactness_err": max_err, "argmax_ok": argmax_ok}


def _measure_wall(kv_len: int, n_splits: int, reps: int = 3) -> float:
    """Informational jnp wall-clock at B=1 (host-bound; not gated)."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 1, HQ, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, kv_len, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, kv_len, HKV, DV)), jnp.float32)
    pos = jnp.int32(kv_len - 1)
    k_pos = ops.ring_positions(pos, kv_len)
    fn = jax.jit(lambda: ops.decode_attention_jnp(
        q, k, v, k_pos, pos, n_splits=n_splits))
    jax.block_until_ready(fn())                   # warm the jit
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return min(times)


def run(quick: bool = False) -> dict:
    kv_lens = [256, 4096] if quick else [256, 2048, 8192, 32768]
    batches = [1, 4]
    split_grid = [1, 2, 4, 8, 16]

    rows = []
    shallow_auto_ratio = float("inf")
    for b in batches:
        for kv in kv_lens:
            base = modelled_tok_per_s(b, kv, 1)
            by_split = {s: modelled_tok_per_s(b, kv, s) for s in split_grid}
            auto_s = choose_kv_splits(b, kv, HQ, N_EXEC, block=BLOCK)
            auto = modelled_tok_per_s(b, kv, auto_s)
            best_s = max(by_split, key=by_split.get)
            rows.append({
                "batch": b, "kv_len": kv, "auto_splits": auto_s,
                "modelled_tok_per_s_single": base,
                "modelled_tok_per_s_auto": auto,
                "modelled_auto_ratio": auto / base,
                "modelled_best_splits": best_s,
                "modelled_best_ratio": by_split[best_s] / base,
                "modelled_tok_per_s_by_splits": by_split,
            })
            shallow_auto_ratio = min(shallow_auto_ratio, auto / base)

    # shallow gate: the auto heuristic must never cost throughput — at any
    # benched point, not just the shallow ones (splits=1 must stay the
    # choice wherever splitting cannot pay for its merge)
    for r in rows:
        if r["modelled_auto_ratio"] < 1.0 - 1e-9:
            raise AssertionError(
                f"two-stage regression: auto splits={r['auto_splits']} gives "
                f"{r['modelled_auto_ratio']:.3f}x single-split tok/s at "
                f"B={r['batch']} KV={r['kv_len']}")

    # deep gate: lowest-batch row at the deepest KV length must scale
    deep = next(r for r in rows
                if r["batch"] == min(batches) and r["kv_len"] == kv_lens[-1])
    deep_speedup = deep["modelled_auto_ratio"]
    if deep_speedup < MIN_DEEP_SPEEDUP:
        raise AssertionError(
            f"split sweep does not scale: {deep_speedup:.2f}x < "
            f"{MIN_DEEP_SPEEDUP}x at B={deep['batch']} KV={deep['kv_len']}")

    exact = _measure_exactness()
    if exact["max_exactness_err"] > EXACT_TOL or not exact["argmax_ok"]:
        raise AssertionError(
            f"two-stage exactness failure: max |err| "
            f"{exact['max_exactness_err']:.2e} (tol {EXACT_TOL:.0e}), "
            f"greedy argmax ok={exact['argmax_ok']}")

    wall_kv = kv_lens[-1]
    wall_single = _measure_wall(wall_kv, 1, reps=2 if quick else 3)
    wall_split = _measure_wall(wall_kv, deep["auto_splits"],
                               reps=2 if quick else 3)

    return {
        "n_exec": N_EXEC,
        "heads": {"q": HQ, "kv": HKV, "d": D, "dv": DV},
        "block": BLOCK,
        "rows": rows,
        "deep_kv_len": deep["kv_len"],
        "deep_speedup": deep_speedup,
        "deep_best_splits": deep["auto_splits"],
        "shallow_auto_ratio": shallow_auto_ratio,
        "max_exactness_err": exact["max_exactness_err"],
        "argmax_ok": exact["argmax_ok"],
        "measured_wall_s_single": wall_single,
        "measured_wall_s_auto": wall_split,
    }


# --------------------------------------------------------------------------
# mla mode — compressed-latent paged decode (the model-zoo headline sweep)
# --------------------------------------------------------------------------
# paper-scale MLA geometry (deepseek-v2): 128 q heads share ONE latent row
# of R = kv_lora_rank + rope_head_dim floats per token
HQ_MLA, R_KV, D_ROPE = 128, 512, 64
R_LAT = R_KV + D_ROPE
MLA_SCALE = (128 + 64) ** -0.5    # decompressed head dim (nope + rope)
# GQA-equivalent serving layout at the same model scale: 8 kv-head groups,
# K rows carry nope+rope (192) lanes and V rows 128 — the cache the engine
# would page for a 128-head model without latent compression
HKV_EQ, DK_EQ, DV_EQ = 8, 192, 128
MIN_KV_BYTES_RATIO = 4.0          # acceptance floor on the ~5x compression


def model_mla_sweep_time(batch: int, kv_len: int, n_splits: int) -> float:
    """Roofline time for one MLA latent sweep.  The natural grid is
    ``(batch, splits, pages)`` — every q head reads the SAME latent row, so
    the page DMA is shared across all 128 heads and the occupancy cell
    count is ``batch * splits`` (q_heads = 1), the deepest occupancy
    deficit in the zoo at low batch."""
    n_blocks = -(-kv_len // BLOCK)
    s = max(1, min(n_splits, n_blocks))
    cells = batch * s
    util = min(1.0, cells / N_EXEC)
    kv_bytes = batch * kv_len * R_LAT * KV_BYTES
    # scores dot q_lat (R lanes) against the row, value reduces r_kv lanes
    flops = 2.0 * batch * HQ_MLA * kv_len * (R_LAT + R_KV)
    t1 = max(kv_bytes / (TPU_V5E.hbm_bw * util),
             flops / (TPU_V5E.peak_flops * TPU_V5E.matmul_efficiency * util))
    t = t1 + LAUNCH_S
    if s > 1:
        merge_bytes = batch * HQ_MLA * (s * (R_KV + 1) + R_KV) * 4
        t += merge_bytes / TPU_V5E.hbm_bw + LAUNCH_S
    return t


def modelled_mla_tok_per_s(batch: int, kv_len: int, n_splits: int) -> float:
    return batch / model_mla_sweep_time(batch, kv_len, n_splits)


def _measure_mla_exactness() -> dict:
    """Real-array parity of every MLA paged backend vs the naive oracle:
    jnp split sweep, interpret-mode Pallas (single and two-stage), and the
    stage-1 partial/LSE contract vs ``ref.mla_decode_split_ref``."""
    from repro.kernels import decode_attention as da
    from repro.kernels import ref

    rng = np.random.default_rng(2)
    B, Hq, r_kv, dr, ps, nb = 2, 8, 32, 16, 4, 8
    R = r_kv + dr
    n_pages = nb * B + 3
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, R)), jnp.float32)
    pages = jnp.asarray(rng.standard_normal((n_pages, ps, R)), jnp.float32)
    tables = jnp.asarray(
        rng.permutation(n_pages)[:B * nb].reshape(B, nb), jnp.int32)
    pos = jnp.asarray([nb * ps - 1, 9], jnp.int32)     # full + ragged
    head = jnp.asarray(rng.standard_normal((Hq * r_kv, 128)), jnp.float32)
    scale = (2 * r_kv / Hq) ** -0.5

    ref_out = ref.mla_decode_paged_ref(q, pages, tables, pos, r_kv=r_kv,
                                       scale=scale)
    ref_arg = jnp.argmax(ref_out.reshape(B, -1) @ head, axis=-1)
    max_err, argmax_ok = 0.0, True

    def check(out):
        nonlocal max_err, argmax_ok
        max_err = max(max_err, float(jnp.max(jnp.abs(out - ref_out))))
        argmax_ok &= bool(jnp.all(
            jnp.argmax(out.reshape(B, -1) @ head, axis=-1) == ref_arg))

    for s in (1, 2, 5):
        check(ops.mla_decode_paged_jnp(q, pages, tables, pos, r_kv=r_kv,
                                       scale=scale, n_splits=s))
    for s in (1, 4):
        check(da.mla_paged_decode_attention_pallas(
            q, pages, tables, pos, r_kv=r_kv, scale=scale, n_splits=s,
            interpret=True))
    # stage-1 contract: Pallas partials vs the split oracle, split by split
    p_ref, l_ref = ref.mla_decode_split_ref(q, pages, tables, pos,
                                            r_kv=r_kv, n_splits=4,
                                            scale=scale)
    p_pal, l_pal = da.mla_paged_decode_attention_pallas_partials(
        q, pages, tables, pos, r_kv=r_kv, n_splits=4, scale=scale,
        interpret=True)
    stage1_err = max(float(jnp.max(jnp.abs(p_ref - p_pal))),
                     float(jnp.max(jnp.abs(l_ref - l_pal))))
    max_err = max(max_err, stage1_err)
    return {"max_exactness_err": max_err, "argmax_ok": argmax_ok,
            "stage1_err": stage1_err}


def run_mla(quick: bool = False) -> dict:
    kv_lens = [256, 4096] if quick else [256, 2048, 8192, 32768]
    batches = [1, 4]
    split_grid = [1, 2, 4, 8, 16]

    rows = []
    shallow_auto_ratio = float("inf")
    for b in batches:
        for kv in kv_lens:
            base = modelled_mla_tok_per_s(b, kv, 1)
            by_split = {s: modelled_mla_tok_per_s(b, kv, s)
                        for s in split_grid}
            # q_heads = 1: all heads ride one page DMA (see mla_decode_paged)
            auto_s = choose_kv_splits(b, kv, 1, N_EXEC, block=BLOCK)
            auto = modelled_mla_tok_per_s(b, kv, auto_s)
            best_s = max(by_split, key=by_split.get)
            rows.append({
                "batch": b, "kv_len": kv, "auto_splits": auto_s,
                "modelled_tok_per_s_single": base,
                "modelled_tok_per_s_auto": auto,
                "modelled_auto_ratio": auto / base,
                "modelled_best_splits": best_s,
                "modelled_best_ratio": by_split[best_s] / base,
                "modelled_tok_per_s_by_splits": by_split,
            })
            shallow_auto_ratio = min(shallow_auto_ratio, auto / base)

    for r in rows:
        if r["modelled_auto_ratio"] < 1.0 - 1e-9:
            raise AssertionError(
                f"mla two-stage regression: auto splits={r['auto_splits']} "
                f"gives {r['modelled_auto_ratio']:.3f}x single-split tok/s "
                f"at B={r['batch']} KV={r['kv_len']}")

    deep = next(r for r in rows
                if r["batch"] == min(batches) and r["kv_len"] == kv_lens[-1])
    deep_speedup = deep["modelled_auto_ratio"]
    if deep_speedup < MIN_DEEP_SPEEDUP:
        raise AssertionError(
            f"mla split sweep does not scale: {deep_speedup:.2f}x < "
            f"{MIN_DEEP_SPEEDUP}x at B={deep['batch']} KV={deep['kv_len']}")

    # KV compression: bytes per token the page pool (and thus every host-tier
    # transfer and CoW copy) carries, latent layout vs the GQA-equivalent —
    # this ratio IS the transfer-energy ratio at fixed J/byte
    mla_bytes = R_LAT * KV_BYTES
    gqa_bytes = HKV_EQ * (DK_EQ + DV_EQ) * KV_BYTES
    kv_ratio = gqa_bytes / mla_bytes
    if kv_ratio < MIN_KV_BYTES_RATIO:
        raise AssertionError(
            f"latent compression regressed: {kv_ratio:.2f}x < "
            f"{MIN_KV_BYTES_RATIO}x KV bytes/token vs GQA-equivalent")
    transfer_j_per_byte = 1e-9          # EngineConfig default
    exact = _measure_mla_exactness()
    if exact["max_exactness_err"] > EXACT_TOL or not exact["argmax_ok"]:
        raise AssertionError(
            f"mla paged exactness failure vs ref oracle: max |err| "
            f"{exact['max_exactness_err']:.2e} (tol {EXACT_TOL:.0e}), "
            f"greedy argmax ok={exact['argmax_ok']}")

    return {
        "n_exec": N_EXEC,
        "geometry": {"q_heads": HQ_MLA, "r_kv": R_KV, "d_rope": D_ROPE,
                     "gqa_eq": {"kv_heads": HKV_EQ, "dk": DK_EQ,
                                "dv": DV_EQ}},
        "block": BLOCK,
        "rows": rows,
        "deep_kv_len": deep["kv_len"],
        "deep_speedup": deep_speedup,
        "deep_best_splits": deep["auto_splits"],
        "shallow_auto_ratio": shallow_auto_ratio,
        "kv_bytes_per_token": mla_bytes,
        "kv_bytes_per_token_gqa_eq": gqa_bytes,
        "kv_bytes_ratio": kv_ratio,
        "transfer_j_per_token": mla_bytes * 2 * transfer_j_per_byte,
        "transfer_j_per_token_gqa_eq": gqa_bytes * 2 * transfer_j_per_byte,
        "max_exactness_err": exact["max_exactness_err"],
        "stage1_err": exact["stage1_err"],
        "argmax_ok": exact["argmax_ok"],
    }


def main_mla(quick: bool = False) -> dict:
    res = run_mla(quick=quick)
    for r in res["rows"]:
        print(f"mla.modelled_tok_per_s,{r['modelled_tok_per_s_auto']:.0f},"
              f"B={r['batch']} KV={r['kv_len']} auto splits="
              f"{r['auto_splits']} ({r['modelled_auto_ratio']:.2f}x single)")
    print(f"mla.deep_speedup,{res['deep_speedup']:.2f}x,"
          f"modelled latent sweep vs single-split at KV={res['deep_kv_len']} "
          f"(S={res['deep_best_splits']}, {res['n_exec']} executors, "
          f"{HQ_MLA} heads / 1 latent row)")
    print(f"mla.kv_bytes_ratio,{res['kv_bytes_ratio']:.2f}x,"
          f"{res['kv_bytes_per_token']} B/token latent vs "
          f"{res['kv_bytes_per_token_gqa_eq']} B GQA-equivalent — same "
          "ratio on every host-tier transfer joule at fixed J/byte")
    print(f"mla.max_exactness_err,{res['max_exactness_err']:.2e},"
          f"jnp+Pallas-interpret vs ref oracle (stage-1 partial/LSE err "
          f"{res['stage1_err']:.2e}; greedy argmax ok={res['argmax_ok']})")
    return res


def main(quick: bool = False) -> dict:
    res = run(quick=quick)
    for r in res["rows"]:
        print(f"kernel.modelled_tok_per_s,{r['modelled_tok_per_s_auto']:.0f},"
              f"B={r['batch']} KV={r['kv_len']} auto splits="
              f"{r['auto_splits']} ({r['modelled_auto_ratio']:.2f}x single)")
    print(f"kernel.deep_speedup,{res['deep_speedup']:.2f}x,"
          f"modelled two-stage vs single-split at KV={res['deep_kv_len']} "
          f"(S={res['deep_best_splits']}, {res['n_exec']} executors)")
    print(f"kernel.max_exactness_err,{res['max_exactness_err']:.2e},"
          f"measured on real arrays (greedy argmax ok={res['argmax_ok']})")
    print(f"kernel.measured_wall_ms,{res['measured_wall_s_auto']*1e3:.3f},"
          f"jnp sweep at KV={res['deep_kv_len']} on this host "
          f"({res['measured_wall_s_single']*1e3:.3f} ms single-stage; "
          "informational — host wall does not see TPU grid occupancy)")
    return res


if __name__ == "__main__":
    main()
