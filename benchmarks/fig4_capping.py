"""Paper Fig 4 — power-capping curves per model (setup no.2, RTX 3090).

For each zoo model: sweep the 8 caps {30..100}%, record energy/epoch and
time/epoch, locate the energy-optimal cap.  Claims: per-model optima mostly
in 40-70%; energy falls much faster than time rises; LeNet is flat (the
GPU never reaches its cap on a tiny model).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SETUP2, epoch_quantities, profile_zoo

CAPS = np.round(np.arange(0.30, 1.001, 0.10), 2)


def run(models=None, steps: int = 12) -> dict:
    runs = profile_zoo(models, train_steps=steps)
    rows = []
    for name, r in runs.items():
        es, ts = [], []
        for cap in CAPS:
            e, t, _, _ = epoch_quantities(r, SETUP2, cap=float(cap))
            es.append(e)
            ts.append(t)
        i_opt = int(np.argmin(es))
        e100, t100 = es[-1], ts[-1]
        rows.append({
            "model": name,
            "caps": CAPS.tolist(),
            "energy_j": es,
            "time_s": ts,
            "optimal_cap": float(CAPS[i_opt]),
            "energy_saving_at_opt": 1 - es[i_opt] / e100,
            "delay_at_opt": ts[i_opt] / t100 - 1,
            "flat": (max(es) - min(es)) / e100 < 0.05,
        })
    return {"rows": rows}


def main(quick: bool = False):
    res = run(models=["LeNet", "ResNet18", "MobileNetV2", "DenseNet121",
                      "EfficientNetB0"] if quick else None,
              steps=8 if quick else 12)
    for r in res["rows"]:
        print(f"fig4.{r['model']},cap*={r['optimal_cap']:.0%},"
              f"dE={r['energy_saving_at_opt']:+.1%} "
              f"dT={r['delay_at_opt']:+.1%}"
              + (" FLAT" if r["flat"] else ""))
    opts = [r["optimal_cap"] for r in res["rows"] if not r["flat"]]
    if opts:
        print(f"fig4.optimal_cap_range,{min(opts):.0%}-{max(opts):.0%},"
              f"paper=40-70%")
    return res


if __name__ == "__main__":
    main()
