"""Paper Fig 5 — fine-grained (1% increments) cap sweep on ResNet and the
ED^xP decision criteria.

Claims: (a) energy has an interior minimum while time decreases
monotonically with cap; (b) the more weight on delay (higher x), the higher
the optimal cap — ED^3P can saturate at 100%; (c) EDP (x=1) gives the
largest energy savings.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SETUP2, epoch_quantities, profile_cnn
from repro.core import CapProfiler, QoSPolicy
from repro.core.powermodel import PowerCappedDevice


def run(model: str = "ResNet18", steps: int = 12) -> dict:
    r = profile_cnn(model, train_steps=steps)
    caps = np.round(np.arange(0.30, 1.001, 0.01), 2)
    es, ts = [], []
    for cap in caps:
        e, t, _, _ = epoch_quantities(r, SETUP2, cap=float(cap))
        es.append(e)
        ts.append(t)
    es, ts = np.asarray(es), np.asarray(ts)

    # ED^xP optima on the fine grid
    optima = {}
    for x in (1.0, 2.0, 3.0):
        cost = (es / es[-1]) * (ts / ts[-1]) ** x
        optima[f"ED{x:g}P"] = float(caps[int(np.argmin(cost))])

    # and through the actual FROST profiler (8 coarse probes + fit)
    wl = r.workload(samples_per_step=128)

    class W:
        dev = SETUP2

        def probe(self, cap, duration_s):
            return self.dev.probe(wl, cap, duration_s)

    frost = {}
    for x in (1.0, 2.0, 3.0):
        d = CapProfiler(W(), policy=QoSPolicy(edp_exponent=x)).run()
        frost[f"ED{x:g}P"] = {"cap": d.cap, "fit_ok": d.fit_accepted,
                              "rel_rmse": d.fit.rel_rmse}
    return {"model": model, "caps": caps.tolist(), "energy": es.tolist(),
            "time": ts.tolist(), "grid_optima": optima, "frost": frost}


def main(quick: bool = False):
    res = run(steps=8 if quick else 12)
    g = res["grid_optima"]
    print(f"fig5.grid_optima,ED1P={g['ED1P']:.0%} ED2P={g['ED2P']:.0%} "
          f"ED3P={g['ED3P']:.0%},monotone={'yes' if g['ED1P'] <= g['ED2P'] <= g['ED3P'] else 'NO'}")
    for k, v in res["frost"].items():
        print(f"fig5.frost_{k},{v['cap']:.0%},fit_rmse={v['rel_rmse']:.3%} "
              f"accepted={v['fit_ok']}")
    return res


if __name__ == "__main__":
    main()
