"""Continuous batching vs the static-batch baseline under Poisson traffic.

The serving question behind the engine: real xAPP inference traffic is a
stream of ragged requests, but a fixed-batch server must group them — every
member of a group waits for the group's longest prompt AND longest
generation, and the device keeps burning joules on slots whose requests
already finished.  Continuous batching admits/frees mid-stream, so its
J/token (charged to *useful* tokens only) and its latency distribution are
both structurally better at equal hardware.

Both servers run the SAME Poisson trace on the same shrunk model:

  a. static  — requests grouped FIFO into batches of ``n_slots``; each
               group prefills padded to its longest prompt and decodes to
               its longest budget in fused ring chunks (the pre-engine
               ``launch/serve.py`` path, expressed on a trace).
  b. engine  — ``repro.serving.ServeEngine``: paged KV cache, prefill-on-
               join, free-on-finish, slot-masked fused chunks.

Energy is the analytic device model at 100% TDP and at the deep cap, per
chunk at the occupancy actually in force.  Emits ``serve.*`` CSV lines and
a JSON artifact (via benchmarks.run) as the continuous-batching perf
trajectory.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import PowerCappedDevice, TPU_V5E
from repro.launch.serve import decode_workload
from repro.models import transformer as tfm
from repro.runtime.steps import (StepConfig, make_decode_loop,
                                 make_prefill_step)
from repro.serving import EngineConfig, ServeEngine, poisson_trace

DEEP_CAP = 0.5


def _energy(device, cfg, n_active: int, n_steps: int, cap: float) -> float:
    est = device.estimate(decode_workload(cfg, n_active), cap)
    return est.energy_j * n_steps


def run_static(cfg, device, trace, *, n_slots: int, chunk: int,
               seed: int = 0) -> dict:
    """FIFO groups of ``n_slots``, padded prefill, run-to-completion."""
    step_cfg = StepConfig(remat="none")
    params, _ = tfm.init_lm(jax.random.PRNGKey(seed), cfg)
    groups = [trace[i:i + n_slots] for i in range(0, len(trace), n_slots)]
    wall = 0.0
    energy = {1.0: 0.0, DEEP_CAP: 0.0}
    useful = computed = 0
    lat_steps = []
    clock = 0
    prefills = {}
    # one jitted loop serves every group: jit retraces per cache shape
    loop = jax.jit(make_decode_loop(cfg, step_cfg, n_tokens=chunk))
    for group in groups:
        Lmax = max(r.prompt_len for r in group)
        gen = max(r.max_new_tokens for r in group)
        # a static server cannot start the group before its last arrival
        clock = max(clock, max(r.arrival_step for r in group))
        if (Lmax, gen) not in prefills:         # max_len bakes in BOTH
            prefills[(Lmax, gen)] = jax.jit(
                make_prefill_step(cfg, step_cfg, max_len=Lmax + gen))
        prompts = np.zeros((n_slots, Lmax), np.int32)
        for i, r in enumerate(group):
            prompts[i, :r.prompt_len] = r.prompt       # pad right
        last_logits, cache = prefills[(Lmax, gen)](
            params, {"inputs": jnp.asarray(prompts)})
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
        n_chunks = -(-(gen - 1) // chunk)
        loop(params, cache, tok)                       # warm the jit
        t0 = time.perf_counter()
        for _ in range(n_chunks):
            toks, cache = loop(params, cache, tok)
            tok = toks[:, -1:]
        jax.block_until_ready(tok)
        wall += time.perf_counter() - t0
        # every slot decodes every step of every chunk, done or not
        for cap in energy:
            energy[cap] += _energy(device, cfg, len(group), n_chunks * chunk,
                                   cap)
        computed += len(group) * n_chunks * chunk
        useful += sum(r.max_new_tokens - 1 for r in group)
        clock += n_chunks * chunk
        lat_steps += [clock - r.arrival_step for r in group]
    return {
        "tok_per_s": useful / max(wall, 1e-9),
        "j_per_token": energy[1.0] / max(useful, 1),
        "j_per_token_deep_cap": energy[DEEP_CAP] / max(useful, 1),
        "useful_tokens": useful,
        "computed_tokens": computed,
        "p50_latency_steps": float(np.percentile(lat_steps, 50)),
        "p95_latency_steps": float(np.percentile(lat_steps, 95)),
    }


def run_engine(cfg, device, trace, *, n_slots: int, chunk: int,
               page_size: int, max_len: int, seed: int = 0) -> dict:
    params, _ = tfm.init_lm(jax.random.PRNGKey(seed), cfg)
    energy = {1.0: 0.0, DEEP_CAP: 0.0}

    def on_chunk(stats):
        for cap in energy:
            energy[cap] += _energy(device, cfg, stats.n_active, chunk, cap)
        return _energy(device, cfg, stats.n_active, chunk, 1.0)

    ecfg = EngineConfig(n_slots=n_slots, page_size=page_size, max_len=max_len,
                        decode_chunk=chunk)
    rep = ServeEngine(cfg, ecfg, params, on_chunk=on_chunk).run(trace)
    lat = rep.latency_percentiles((50, 95))
    return {
        "tok_per_s": rep.tok_per_s,
        "j_per_token": energy[1.0] / max(rep.tokens_kept, 1),
        "j_per_token_deep_cap": energy[DEEP_CAP] / max(rep.tokens_kept, 1),
        "useful_tokens": rep.tokens_kept,
        "computed_tokens": rep.tokens_computed,
        "occupancy": rep.occupancy,
        "p50_latency_steps": lat[50],
        "p95_latency_steps": lat[95],
    }


def run(quick: bool = False) -> dict:
    spec = get_arch("smollm-135m")
    # shrunk below the smoke config: the benchmark contrasts SCHEDULING
    # regimes, so per-step device compute must not drown the grouping,
    # padding, and idle-slot costs the two servers differ on
    cfg = dataclasses.replace(spec.smoke, d_model=64, d_ff=128, head_dim=16,
                              name=spec.smoke.name + "-bench")
    device = PowerCappedDevice(TPU_V5E)
    n_req = 8 if quick else 16
    n_slots, chunk, page_size = 4, 8, 8
    prompt_len, gen = (6, 24), (4, 24)
    trace = poisson_trace(n_req, rate_per_step=0.15, seed=17,
                          vocab_size=cfg.vocab_size, prompt_len=prompt_len,
                          max_new_tokens=gen)
    eng = run_engine(cfg, device, trace, n_slots=n_slots, chunk=chunk,
                     page_size=page_size, max_len=prompt_len[1] + gen[1])
    sta = run_static(cfg, device, trace, n_slots=n_slots, chunk=chunk)
    return {
        "arch": cfg.name,
        "n_requests": n_req,
        "n_slots": n_slots,
        "deep_cap": DEEP_CAP,
        "engine": eng,
        "static": sta,
        "tok_per_s": eng["tok_per_s"],
        "j_per_token_ratio": sta["j_per_token"] / max(eng["j_per_token"], 1e-12),
        "p50_latency_ratio": sta["p50_latency_steps"]
        / max(eng["p50_latency_steps"], 1e-9),
    }


def main(quick: bool = False) -> dict:
    res = run(quick=quick)
    for name in ("engine", "static"):
        r = res[name]
        print(f"serve.{name}_tok_per_s,{r['tok_per_s']:.1f},"
              f"useful tokens / decode wall ({r['useful_tokens']} useful, "
              f"{r['computed_tokens']} computed)")
        print(f"serve.{name}_j_per_token,{r['j_per_token']:.3g},"
              f"analytic @100% TDP ({r['j_per_token_deep_cap']:.3g} "
              f"@{res['deep_cap']:.0%} cap), useful tokens only")
        print(f"serve.{name}_p50_latency,{r['p50_latency_steps']:.0f},"
              f"steps (p95 {r['p95_latency_steps']:.0f})")
    print(f"serve.j_per_token_ratio,{res['j_per_token_ratio']:.2f}x,"
          f"static / engine — continuous batching charges only occupied slots")
    print(f"serve.p50_latency_ratio,{res['p50_latency_ratio']:.2f}x,"
          f"static / engine under the same Poisson trace")
    return res


if __name__ == "__main__":
    main()
