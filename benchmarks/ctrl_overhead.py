"""Control-plane overhead — the Fig 3 question asked of the new event bus.

The paper's claim (Fig 3) is that FROST's 0.1 Hz sampler is ~free next to
the pipeline.  The control-plane refactor adds per-step work: a ``StepDone``
publish, the online profiler's bucket update, and (amortised) F(x) refits.
This benchmark measures that cost per step, isolated from any model:

  a. bare loop                      — the floor,
  b. bus publish, no subscribers    — dispatch cost alone,
  c. bus + OnlineCapProfiler        — the full closed loop, refits included,
  d. 0.1 Hz PowerSampler (paper)    — the baseline FROST telemetry path.

Claim to verify: (c) stays within single-digit microseconds per step —
orders of magnitude below any real train/decode step — so closing the loop
costs nothing the paper's sampler didn't already pay.
"""
from __future__ import annotations

import time

from repro.control import EventBus, StepDone
from repro.control.online import OnlineCapProfiler
from repro.core import BALANCED, PowerCappedDevice, TPU_V5E, WorkloadProfile
from repro.core.profiler import RecordingBackend
from repro.telemetry.meters import CpuProcessMeter, DramMeter
from repro.telemetry.sampler import PowerSampler

_WL = WorkloadProfile(name="ctrl-bench", flops_per_step=1.2e12,
                      hbm_bytes_per_step=6e9, samples_per_step=256)


def _loop_bare(n: int) -> float:
    t0 = time.perf_counter()
    acc = 0.0
    for i in range(n):
        acc += i * 1e-9                       # keep the loop honest
    dt = time.perf_counter() - t0
    assert acc >= 0
    return dt


def _loop_bus_only(n: int) -> float:
    bus = EventBus(history=64)
    ev = [StepDone(node_id="bench-0", step=i, duration_s=1e-3, samples=256,
                   energy_j=0.2) for i in range(64)]
    t0 = time.perf_counter()
    for i in range(n):
        bus.publish(ev[i % 64])
    return time.perf_counter() - t0


def _loop_online(n: int) -> tuple[float, float, int, int]:
    bus = EventBus(history=64)
    backend = RecordingBackend()
    dev = PowerCappedDevice(TPU_V5E)
    prof = OnlineCapProfiler(bus, backend, policy=BALANCED,
                             node_id="bench-0", steps_per_probe=2,
                             hold_steps=32)
    # Cache the simulated telemetry per cap: reading NVML (or the analytic
    # stand-in) is the pipeline's cost, not the control plane's.
    est_cache: dict[float, tuple[float, float]] = {}

    def telemetry(cap: float) -> tuple[float, float]:
        hit = est_cache.get(cap)
        if hit is None:
            e = dev.estimate(_WL, cap)
            hit = est_cache[cap] = (e.step_time_s, e.energy_j)
        return hit

    # Phase 1 (first 100 steps) contains the initial sweep + multi-start fit
    # — the one-time profile cost the batch profiler also pays.  Phase 2 is
    # the steady state: bucket update + dispatch, refits rate-limited.
    warm = min(100, n)
    t0 = time.perf_counter()
    for i in range(warm):
        duration_s, energy_j = telemetry(backend.current_cap())
        bus.publish(StepDone(node_id="bench-0", step=i,
                             duration_s=duration_s, samples=256,
                             energy_j=energy_j))
    t_sweep = time.perf_counter() - t0
    t0 = time.perf_counter()
    for i in range(warm, n):
        duration_s, energy_j = telemetry(backend.current_cap())
        bus.publish(StepDone(node_id="bench-0", step=i,
                             duration_s=duration_s, samples=256,
                             energy_j=energy_j))
    t_steady = time.perf_counter() - t0
    prof.close()
    return t_sweep, t_steady, n - warm, prof.n_refits


def _loop_sampler(n: int) -> float:
    sampler = PowerSampler({"cpu": CpuProcessMeter(), "dram": DramMeter(4, 16)},
                           rate_hz=0.1)
    with sampler:
        t0 = time.perf_counter()
        acc = 0.0
        for i in range(n):
            acc += i * 1e-9
        dt = time.perf_counter() - t0
    assert acc >= 0
    return dt


def run(n_steps: int = 20_000) -> dict:
    t_bare = _loop_bare(n_steps)
    t_bus = _loop_bus_only(n_steps)
    t_sweep, t_steady, n_steady, n_refits = _loop_online(n_steps)
    t_sampler = _loop_sampler(n_steps)
    floor_per_step = t_bare / n_steps
    per = lambda t, n: (t / n - floor_per_step) * 1e6 if n else 0.0
    return {
        "n_steps": n_steps,
        "bare_s": t_bare,
        "bus_publish_us_per_step": per(t_bus, n_steps),
        "online_sweep_s": t_sweep,                 # one-time profile cost
        "online_steady_us_per_step": per(t_steady, n_steady),
        "online_refits": n_refits,
        "sampler_0p1hz_us_per_step": per(t_sampler, n_steps),
    }


def main(quick: bool = False):
    res = run(n_steps=4_000 if quick else 20_000)
    print(f"ctrl.bus_publish,{res['bus_publish_us_per_step']:.2f}us/step,"
          f"dispatch only")
    print(f"ctrl.online_sweep,{res['online_sweep_s']:.2f}s,"
          f"one-time: initial sweep + multi-start F(x) fit")
    print(f"ctrl.online_steady,{res['online_steady_us_per_step']:.2f}us/step,"
          f"closed loop steady state ({res['online_refits']} refits total)")
    print(f"ctrl.sampler_0.1hz,{res['sampler_0p1hz_us_per_step']:.2f}us/step,"
          f"paper Fig 3 baseline")
    extra = (res["online_steady_us_per_step"]
             - res["sampler_0p1hz_us_per_step"])
    print(f"ctrl.loop_extra_cost,{extra:.2f}us/step,"
          f"steady-state closed loop minus 0.1Hz sampler baseline")
    return res


if __name__ == "__main__":
    main()
