"""Chaos drill: fault-tolerant serving under crash + power emergency.

FROST's serving story only matters if it survives contact with the fleet:
nodes crash mid-decode, telemetry drops, and the power emergency that
motivates capping in the first place arrives as a *fault*, not a config.
This benchmark runs the SAME Poisson trace twice on the same shrunk model:

  a. baseline — fault-free ``ServeEngine`` run (the PR-5 engine),
  b. chaos    — a seeded :class:`FaultInjector` schedules a slot crash, a
               KV-page corruption, a mid-run ``engine_crash``, and an
               emergency-cap window on the engine's decode-step clock.
               The engine snapshots every few chunks; the crash is
               recovered here (``ServeEngine.restore`` + ``resume``) with
               the dead engine's in-flight requests requeued, their
               generated tokens folded into the prompt.

Energy is modelled per chunk at the cap in force: healthy chunks at 100%
TDP, emergency-window chunks at the cap the fault carried — degradation
(paused admission, halved decode chunk) shrinks the work under the cap
instead of violating it.  The headline numbers are the *cost of
surviving*: recovery latency, requests requeued, J/token overhead vs the
fault-free run, and tokens lost — which MUST be zero.

This benchmark is the CI correctness gate for the fault-tolerance
subsystem: it RAISES if any per-request greedy stream differs between the
two runs (crash recovery, corruption quarantine, and degradation must all
be invisible in the output), if the crash was never injected, or if no
chunk ran degraded during the emergency window.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time

import numpy as np

from repro.configs import get_arch
from repro.core import PowerCappedDevice, TPU_V5E
from repro.launch.serve import decode_workload
from repro.models import transformer as tfm
from repro.runtime.chaos import FaultInjector
from repro.serving import (EngineConfig, EngineCrash, ServeEngine,
                           poisson_trace)

import jax

EMERGENCY_CAP = 0.5
MAX_RESTARTS = 3


def _run(cfg, device, trace, ecfg, params, *, injector=None,
         snapshot_dir=None, snapshot_every=0) -> dict:
    energy = {"j": 0.0}
    beats = {"n": 0}

    def on_chunk(stats):
        # emergency-window chunks are priced at the cap the fault carried —
        # the degraded engine must fit its (halved) work under that cap
        cap = EMERGENCY_CAP if stats.degrade_level >= 2 else 1.0
        est = device.estimate(decode_workload(cfg, stats.n_active), cap)
        j = est.energy_j * ecfg.decode_chunk
        energy["j"] += j
        return j

    def on_heartbeat(step, wall_s):
        beats["n"] += 1

    eng = ServeEngine(cfg, ecfg, params, on_chunk=on_chunk,
                      on_heartbeat=on_heartbeat, injector=injector,
                      snapshot_dir=snapshot_dir,
                      snapshot_every=snapshot_every)
    restarts = 0
    recovery_s = 0.0
    t0 = time.perf_counter()
    while True:
        try:
            rep = eng.resume() if restarts else eng.run(trace)
            break
        except EngineCrash:
            restarts += 1
            if snapshot_dir is None or restarts > MAX_RESTARTS:
                raise
            t_r = time.perf_counter()
            eng = ServeEngine.restore(cfg, ecfg, params, snapshot_dir,
                                      on_chunk=on_chunk,
                                      on_heartbeat=on_heartbeat,
                                      injector=injector,
                                      snapshot_every=snapshot_every)
            recovery_s += time.perf_counter() - t_r
    wall_s = time.perf_counter() - t0
    lat = rep.latency_percentiles((50, 95))
    return {
        "tok_per_s": rep.tok_per_s,
        "useful_tokens": rep.tokens_kept,
        "j_per_token": energy["j"] / max(rep.tokens_kept, 1),
        "wall_s": wall_s,
        "recovery_latency_s": recovery_s,
        "n_restores": rep.n_restores,
        "n_faults_injected": rep.n_faults_injected,
        "requests_requeued": rep.requeued_requests,
        "degraded_steps": rep.degraded_steps,
        "n_pages_quarantined": rep.n_pages_quarantined,
        "n_heartbeats": beats["n"],
        "p50_latency_steps": lat[50],
        "p95_latency_steps": lat[95],
        "tokens": {r.rid: list(np.asarray(r.tokens).ravel())
                   for r in rep.results},
    }


def run(quick: bool = False) -> dict:
    spec = get_arch("smollm-135m")
    # shrunk below the smoke config: the benchmark measures recovery
    # mechanics and accounting, not model compute
    cfg = dataclasses.replace(spec.smoke, d_model=64, d_ff=128, head_dim=16,
                              name=spec.smoke.name + "-bench")
    device = PowerCappedDevice(TPU_V5E)
    n_req = 6 if quick else 12
    ecfg = EngineConfig(n_slots=2, page_size=4, max_len=32, decode_chunk=4)
    trace = poisson_trace(n_req, rate_per_step=0.4, seed=31,
                          vocab_size=cfg.vocab_size, prompt_len=(4, 12),
                          max_new_tokens=(6, 16))
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)

    base = _run(cfg, device, trace, ecfg, params)

    # the full fault menu, all on the engine's decode-step clock: a slot
    # dies, a KV page corrupts (audit + quarantine), the whole engine
    # crashes mid-run, and a power emergency forces degraded service
    injector = FaultInjector(seed=7)
    injector.schedule("slot_crash", 8, arg=1)
    injector.schedule("page_corrupt", 12)
    injector.schedule("engine_crash", 16)
    injector.schedule("emergency_cap", 28, duration=12, arg=EMERGENCY_CAP)
    snap = tempfile.mkdtemp(prefix="chaos_bench_")
    cha = _run(cfg, device, trace, ecfg, params, injector=injector,
               snapshot_dir=snap, snapshot_every=2)

    # correctness gates (CI smoke): recovery must be invisible in the
    # output — every greedy stream identical, zero tokens lost
    tokens_lost = 0
    for rid, a in base.pop("tokens").items():
        b = cha["tokens"].get(rid, [])
        if a != b:
            raise RuntimeError(
                f"chaos run diverged from fault-free run on rid {rid}: "
                f"{a[:8]} vs {b[:8]} — crash recovery broke greedy "
                "exactness")
        tokens_lost += max(0, len(a) - len(b))
    cha.pop("tokens")
    if cha["n_restores"] < 1:
        raise RuntimeError("engine_crash was scheduled but never recovered "
                           "(n_restores == 0)")
    if cha["degraded_steps"] <= 0:
        raise RuntimeError("emergency_cap window produced no degraded "
                           "steps — graceful degradation never engaged")
    if tokens_lost != 0:
        raise RuntimeError(f"{tokens_lost} tokens lost across the crash — "
                           "snapshot/restore dropped committed work")
    return {
        "arch": cfg.name,
        "n_requests": n_req,
        "emergency_cap": EMERGENCY_CAP,
        "fault_schedule": [f"{e.kind}@{e.step}" for e in injector.log],
        "tokens_lost": tokens_lost,
        "recovery_latency_s": cha["recovery_latency_s"],
        "n_restores": cha["n_restores"],
        "requests_requeued": cha["requests_requeued"],
        "degraded_steps": cha["degraded_steps"],
        "n_pages_quarantined": cha["n_pages_quarantined"],
        "j_per_token_overhead": cha["j_per_token"]
        / max(base["j_per_token"], 1e-12),
        "wall_overhead": cha["wall_s"] / max(base["wall_s"], 1e-9),
        "tok_per_s": cha["tok_per_s"],
        "baseline": base,
        "chaos": cha,
    }


def main(quick: bool = False) -> dict:
    res = run(quick=quick)
    print(f"chaos.faults,{len(res['fault_schedule'])},"
          f"injected on the decode clock: {' '.join(res['fault_schedule'])}")
    print(f"chaos.tokens_lost,{res['tokens_lost']},"
          f"across {res['n_restores']} crash-restores (must be 0; greedy "
          "streams bit-identical to fault-free run)")
    print(f"chaos.recovery_latency_s,{res['recovery_latency_s']:.3f},"
          f"wall time to restore + requeue {res['requests_requeued']} "
          "in-flight requests")
    print(f"chaos.degraded_steps,{res['degraded_steps']},"
          f"decode steps under the {res['emergency_cap']:.0%} emergency cap "
          "(admission paused, chunk halved)")
    print(f"chaos.pages_quarantined,{res['n_pages_quarantined']},"
          f"corrupted KV pages withheld from the free list by the audit")
    print(f"chaos.j_per_token_overhead,{res['j_per_token_overhead']:.2f}x,"
          f"chaos / fault-free J/token (recompute after restore + degraded "
          "chunks)")
    return res


if __name__ == "__main__":
    main()
