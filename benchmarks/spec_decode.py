"""Speculative decoding vs the plain fused decode loop.

The decode hot path is memory-bound: every generated token streams the
whole KV cache + parameter set once, so J/token is set by bytes moved, not
FLOPs (PAPER.md Sec IV — the reason deep power caps are near-free while
serving).  Speculative decoding amortises ONE such sweep over K drafted
tokens plus a bonus: at acceptance ``a`` the same bytes buy ``1 + a*K``
tokens, so tok/s rises and modelled J/token falls by the same factor —
throughput *and* energy, the paper's trade, from one kernel change.

Two fixtures on the shrunk smoke model, per K:

  * ``replay``  — drafts replay the model's own recorded greedy stream:
    acceptance is 1.0 by construction IFF verify/accept/commit are exact,
    so this is simultaneously the ideal-acceptance upper bound for the K
    sweep and the CI canary (``benchmarks.run`` fails the smoke if it ever
    dips below 1.0 — any masking or commit bug shows up here first).
  * ``ngram``   — the production self-drafter (prompt-lookup) on a
    deliberately repetitive prompt, the regime real serving traffic
    (code, RAG quotes, boilerplate) actually occupies.

Greedy speculative output is bit-identical to the plain loop (asserted in
tests/test_speculative.py); this benchmark only measures the rate at which
the identical stream is produced.  Emits ``spec.*`` CSV lines and a JSON
artifact (via benchmarks.run) as the speculative perf trajectory.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import PowerCappedDevice, TPU_V5E, WorkloadProfile
from repro.models import transformer as tfm
from repro.runtime.speculate import NgramDrafter, ReplayDrafter
from repro.runtime.steps import (StepConfig, make_decode_loop,
                                 make_prefill_step,
                                 make_speculative_decode_loop)

DEEP_CAP = 0.5


def _j_per_sweep(cfg, cap: float) -> float:
    """Analytic joules for ONE decode/verify cache sweep (B=1) under
    ``cap`` — speculation does not change this number, it changes how many
    tokens each sweep yields."""
    p = float(cfg.param_count())
    wl = WorkloadProfile(name=f"{cfg.name}-decode",
                         flops_per_step=2.0 * p,
                         hbm_bytes_per_step=2.0 * p,
                         samples_per_step=1)
    return PowerCappedDevice(TPU_V5E).estimate(wl, cap).energy_j


def _best_of(fn, reps: int = 3) -> float:
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def bench_one(cfg, params, cache, tok0, prompts, *, k: int, gen: int,
              ref_stream: np.ndarray) -> dict:
    """One K point: replay (ideal acceptance) + ngram (self-draft)."""
    step_cfg = StepConfig(remat="none")
    B = tok0.shape[0]
    rows = {}

    # -- replay: n_steps sized so the recorded stream covers every draft --
    n_steps = -(-gen // (k + 1))
    replay = ReplayDrafter(k, ref_stream)
    loop = jax.jit(make_speculative_decode_loop(
        cfg, step_cfg, n_steps=n_steps, drafter=replay))
    ds0 = {kk: jnp.asarray(v) for kk, v in replay.init_state(B).items()}
    toks, counts, _, _ = loop(params, cache, tok0, ds0)
    counts = np.asarray(jax.block_until_ready(counts))
    emitted = int(counts.sum())
    rows["replay_acceptance"] = \
        (emitted - counts.size) / max(counts.size * k, 1)
    t = _best_of(lambda: jax.block_until_ready(
        loop(params, cache, tok0, ds0)[1]))
    rows["replay_tok_per_s"] = emitted / max(t, 1e-9)
    rows["replay_wall_s"] = t
    rows["replay_tokens_per_sweep"] = emitted / counts.size

    # -- ngram: the production drafter on the repetitive prompt ----------
    n_steps_n = max(gen // 2, 1)
    ngram = NgramDrafter(k, hist_len=64)
    loop_n = jax.jit(make_speculative_decode_loop(
        cfg, step_cfg, n_steps=n_steps_n, drafter=ngram))
    ds = ngram.init_state(B)
    ngram.seed_batch(ds, np.asarray(prompts), np.asarray(tok0))
    ds = {kk: jnp.asarray(v) for kk, v in ds.items()}
    counts_n = np.asarray(jax.block_until_ready(
        loop_n(params, cache, tok0, ds)[1]))
    emitted_n = int(counts_n.sum())
    rows["ngram_acceptance"] = \
        (emitted_n - counts_n.size) / max(counts_n.size * k, 1)
    t_n = _best_of(lambda: jax.block_until_ready(
        loop_n(params, cache, tok0, ds)[1]))
    rows["ngram_tok_per_s"] = emitted_n / max(t_n, 1e-9)
    rows["ngram_tokens_per_sweep"] = emitted_n / counts_n.size

    # modelled energy: J per sweep is fixed; tokens per sweep divide it
    for cap, tag in ((1.0, "cap100"), (DEEP_CAP, "deep_cap")):
        e = _j_per_sweep(cfg, cap) / max(B, 1)
        rows[f"j_per_accepted_token_{tag}"] = \
            e / max(rows["replay_tokens_per_sweep"], 1e-9)
        rows[f"j_per_token_plain_{tag}"] = e
    rows["k"] = k
    return rows


def run(quick: bool = False) -> dict:
    spec = get_arch("smollm-135m")
    # shrunk below the smoke config, same rationale as decode_throughput:
    # the win being measured is sweeps-per-token, so per-sweep device
    # compute must stay comparable between the plain and verify loops
    cfg = dataclasses.replace(spec.smoke, d_model=64, d_ff=128, head_dim=16,
                              name=spec.smoke.name + "-bench")
    step_cfg = StepConfig(remat="none")
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    gen = 32 if quick else 64
    prompt_len, B = 16, 1          # B=1: the ring loop advances in lockstep
    cache_len = prompt_len + gen * 2

    prefill = jax.jit(make_prefill_step(cfg, step_cfg, max_len=cache_len))
    # repetitive prompt: the high-acceptance regime for prompt-lookup
    pat = jax.random.randint(jax.random.PRNGKey(3), (B, 4), 0, cfg.vocab_size)
    prompts = jnp.tile(pat, (1, prompt_len // 4))
    last_logits, cache = prefill(params, {"inputs": prompts})
    tok0 = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
    jax.block_until_ready(cache)

    # plain fused-loop baseline
    plain = jax.jit(make_decode_loop(cfg, step_cfg, n_tokens=gen))
    t_plain = _best_of(lambda: jax.block_until_ready(
        plain(params, cache, tok0)[0]))
    plain_tok_per_s = (gen * B) / max(t_plain, 1e-9)

    ks = [2] if quick else [1, 2, 4]
    # recorded stream for the replay drafter: every K's run emits
    # ceil(gen/(K+1)) * (K+1) <= gen + max(ks) tokens, and the last step
    # drafts up to that index — cover it fully so the acceptance==1.0 gate
    # tests verify/commit exactness, never the out-of-stream fallback
    plain_long = jax.jit(make_decode_loop(cfg, step_cfg,
                                          n_tokens=gen + max(ks) + 1))
    ref_stream = np.asarray(jax.block_until_ready(
        plain_long(params, cache, tok0)[0]))
    rows = [bench_one(cfg, params, cache, tok0, prompts, k=k, gen=gen,
                      ref_stream=ref_stream) for k in ks]
    for r in rows:
        r["replay_speedup"] = r["replay_tok_per_s"] / max(plain_tok_per_s, 1e-9)
        r["ngram_speedup"] = r["ngram_tok_per_s"] / max(plain_tok_per_s, 1e-9)
    head = max(rows, key=lambda r: r["replay_tok_per_s"])
    return {
        "arch": cfg.name,
        "gen": gen,
        "deep_cap": DEEP_CAP,
        "plain_tok_per_s": plain_tok_per_s,
        "rows": rows,
        "tok_per_s": head["replay_tok_per_s"],
        "speedup": head["replay_speedup"],
        "best_k": head["k"],
        "acceptance": min(r["replay_acceptance"] for r in rows),
        "j_per_token": head["j_per_accepted_token_cap100"],
        "j_per_token_plain": head["j_per_token_plain_cap100"],
    }


def main(quick: bool = False) -> dict:
    res = run(quick=quick)
    print(f"spec.plain_tok_per_s,{res['plain_tok_per_s']:.1f},"
          f"fused decode loop baseline (gen {res['gen']})")
    for r in res["rows"]:
        print(f"spec.tok_per_s,{r['replay_tok_per_s']:.1f},"
              f"K={r['k']} replay (ideal acceptance), "
              f"{r['replay_speedup']:.2f}x over plain fused loop")
        print(f"spec.acceptance,{r['replay_acceptance']:.3f},"
              f"K={r['k']} replay fixture (must be 1.0 — commit canary)")
        print(f"spec.ngram_tok_per_s,{r['ngram_tok_per_s']:.1f},"
              f"K={r['k']} prompt-lookup, acceptance "
              f"{r['ngram_acceptance']:.2f} "
              f"({r['ngram_tokens_per_sweep']:.2f} tok/sweep)")
        print(f"spec.j_per_accepted_token,{r['j_per_accepted_token_cap100']:.3g},"
              f"K={r['k']} analytic @100% TDP vs "
              f"{r['j_per_token_plain_cap100']:.3g} plain "
              f"({r['j_per_accepted_token_deep_cap']:.3g} @{res['deep_cap']:.0%}"
              " cap)")
    print(f"spec.speedup,{res['speedup']:.2f}x,"
          f"best replay K={res['best_k']} vs plain fused loop "
          "(same bytes, more tokens)")
    # CI canary: the replay fixture's drafts ARE the greedy stream, so any
    # acceptance < 1.0 means verify/accept/commit broke exactness
    if res["acceptance"] < 1.0:
        raise RuntimeError(
            f"speculative replay acceptance {res['acceptance']:.3f} < 1.0 — "
            "verify/commit exactness regression")
    return res


if __name__ == "__main__":
    main()
