"""Roofline analysis — deliverable (g).

Reads the dry-run artifacts (artifacts/dryrun/<mesh>/*.json) and derives,
per (arch x shape x mesh):

    compute term    = HLO dot FLOPs / peak FLOP/s          (per chip)
    memory term     = HBM-traffic proxy / HBM bandwidth    (per chip)
    collective term = sum over collective ops of
                        bytes x op_factor / link bandwidth (per chip)

(all three loop-adjusted via the known_trip_count rollup in
launch/hloparse), plus:

    MODEL_FLOPS     = 6 N_active D (train), 2 N_active D (prefill),
                      2 N_active B (decode)   [D = tokens/step]
    usefulness      = MODEL_FLOPS / HLO_FLOPs (remat/replication waste)
    bottleneck      = argmax of the three terms
    roofline_frac   = dominant-term seconds / sum-of-terms seconds... no:
                      fraction of the *ideal* (= compute-term) time, i.e.
                      compute_term / max(term) — 1.0 means perfectly
                      compute-bound (the MXU is the roof).

Hardware constants (assignment brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI per chip.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any

from repro.configs import ARCH_SPECS, SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

# per-op link-traffic factor on the parsed RESULT bytes
_COLL_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def memory_bytes_per_device(arch_id: str, shape_name: str, n_devices: int,
                            step_cfg: dict) -> float:
    """Analytic per-device HBM traffic per step.

    The HLO operand+result proxy is recorded in the artifacts but OVERCOUNTS
    on CPU-lowered HLO (tiny fusion granularity counts every elementwise
    intermediate); the TPU roofline memory term is therefore derived from
    the step structure:

      params:  FSDP-gathered weights are READ by compute once per microbatch
               per pass (fwd + bwd-recompute under remat) in bf16.  MoE
               "gather" strategy touches ALL experts; "a2a" only the local
               shard's experts (the whole point of that strategy).
      acts:    ~12 HBM touches of the (tokens_loc x d) residual stream per
               layer (qkv/mlp in+out, norms, residual adds; flash-attention
               internals stay in VMEM).
      cache:   decode reads the local KV/state shard once per step and
               writes one slot; prefill writes it once.
      logits:  fp32 logits read+write, vocab-sharded 16-way.
    """
    cfg = ARCH_SPECS[arch_id].config
    shape = SHAPES[shape_name]
    n_micro = int(step_cfg.get("n_micro", 1))
    strategy = step_cfg.get("moe_strategy", "gather")
    tp = 16
    batch_shards = n_devices // tp
    d, L = cfg.d_model, cfg.n_layers

    p_total = cfg.param_count()
    if cfg.uses_moe:
        p_experts = p_total - cfg.active_param_count() \
            + (cfg.n_experts and (cfg.experts_per_token
                                  * 3 * d * cfg.resolved_moe_d_ff
                                  * (L - cfg.first_dense_layers)))
        p_experts = (cfg.n_experts * 3 * d * cfg.resolved_moe_d_ff
                     * (L - cfg.first_dense_layers))
        p_dense = p_total - p_experts
    else:
        p_experts, p_dense = 0, p_total

    if strategy == "a2a" and cfg.uses_moe:
        p_touched = p_dense + p_experts / batch_shards
    else:
        p_touched = p_total
    p_bytes = 2.0 * p_touched                      # bf16 gathered weights

    # decode cache: bytes of the full cache / devices (sharded), read per step
    def cache_bytes():
        B, S = shape.global_batch, shape.seq_len
        if cfg.uses_ssm:
            H, P_, N = cfg.resolved_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            per = H * P_ * N * 4 + (cfg.conv_width - 1) * (2 * d + 2 * cfg.ssm_groups * cfg.ssm_state) * 2
            total = L * B * per
            if cfg.family == "hybrid" and cfg.hybrid_attn_every:
                # shared-attn KV grows with context — dominant at long_500k
                nu = L // cfg.hybrid_attn_every
                total += nu * B * S * cfg.padded_kv_heads \
                    * cfg.resolved_head_dim * 2 * 2
            return total
        if cfg.use_mla:
            return L * B * S * (cfg.kv_lora_rank + cfg.rope_head_dim) * 2
        per_layer_cap = min(S, cfg.sliding_window) if cfg.sliding_window else S
        if cfg.local_global:
            cap = (min(S, cfg.local_window) + S) / 2
        else:
            cap = per_layer_cap
        return L * B * cap * cfg.padded_kv_heads * cfg.resolved_head_dim * 2 * 2

    Vp = -(-cfg.vocab_size // 256) * 256

    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len // batch_shards
        params_io = p_bytes * n_micro * 2          # fwd + bwd re-gather
        acts_io = toks * d * 2 * L * 12
        logits_io = toks * (Vp // tp) * 4 * 4
        opt_io = (p_total / n_devices) * 4 * 6     # adamw read+write x3
        return params_io + acts_io + logits_io + opt_io
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len // batch_shards
        return p_bytes + toks * d * 2 * L * 8 + cache_bytes() / n_devices \
            + toks * (Vp // tp) * 4 * 2
    # decode
    b_loc = max(1, shape.global_batch // batch_shards)
    return p_bytes + cache_bytes() / n_devices + b_loc * d * 2 * L * 8


def model_flops_per_device(arch_id: str, shape_name: str, n_devices: int) -> float:
    cfg = ARCH_SPECS[arch_id].config
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * toks
    elif shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * toks
    else:                                  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_devices


def analyze_record(rec: dict[str, Any]) -> dict[str, Any]:
    n_dev = rec["n_devices"]
    t_c = rec["flops_per_device"] / PEAK_FLOPS
    t_m = memory_bytes_per_device(rec["arch"], rec["shape"], n_dev,
                                  rec.get("step_cfg", {})) / HBM_BW
    # the HLO operand+result proxy (loop-adjusted) as recorded upper bound
    t_m_hlo = rec.get("hbm_bytes_per_device", 0.0) / HBM_BW
    coll_s = 0.0
    for op, v in rec.get("collectives", {}).items():
        coll_s += v["bytes"] * _COLL_FACTOR.get(op, 1.0) / LINK_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], n_dev)
    useful = mf / rec["flops_per_device"] if rec["flops_per_device"] else 0.0
    bound = max(terms.values())
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "memory_s_hlo_upper": round(t_m_hlo, 6),
        "bottleneck": dom.replace("_s", ""),
        "model_flops_per_device": mf,
        "usefulness": round(useful, 4),
        # fraction of the roofline: ideal MODEL-FLOPS time / achievable
        # step time (max of terms) — the score we hillclimb
        "roofline_fraction": round((mf / PEAK_FLOPS) / bound, 4) if bound else 0.0,
        "step_time_bound_s": round(bound, 6),
    }


def load_cells(mesh: str = "single", art_dir: pathlib.Path | None = None):
    d = (art_dir or ART) / mesh
    cells = []
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            cells.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                          "status": "fail", "error": rec.get("error", "")})
            continue
        cells.append({"arch": rec["arch"], "shape": rec["shape"],
                      "status": "ok", **analyze_record(rec),
                      "compile_s": rec["seconds_compile"],
                      "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
                      "arg_gib": rec["memory"]["argument_bytes"] / 2**30})
    return cells


def markdown_table(cells) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | roofline frac | useful | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for c in cells:
        if c["status"] != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | FAIL "
                         f"{c['error'][:40]} | | | | | | |")
            continue
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['compute_s']:.4f} | "
            f"{c['memory_s']:.4f} | {c['collective_s']:.4f} | "
            f"{c['bottleneck']} | {c['roofline_fraction']:.3f} | "
            f"{c['usefulness']:.3f} | {c['temp_gib']:.1f} |")
    return "\n".join(lines)


def main(mesh: str = "single"):
    cells = load_cells(mesh)
    ok = [c for c in cells if c["status"] == "ok"]
    for c in ok:
        print(f"roofline.{c['arch']}.{c['shape']},{c['roofline_fraction']:.3f},"
              f"bound={c['bottleneck']} c={c['compute_s']:.3f}s "
              f"m={c['memory_s']:.3f}s x={c['collective_s']:.3f}s")
    if ok:
        worst = min(ok, key=lambda c: c["roofline_fraction"])
        coll = max(ok, key=lambda c: c["collective_s"])
        print(f"roofline.worst_cell,{worst['arch']}x{worst['shape']},"
              f"frac={worst['roofline_fraction']:.3f}")
        print(f"roofline.most_collective_bound,{coll['arch']}x{coll['shape']},"
              f"x={coll['collective_s']:.3f}s")
    out = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "roofline"
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{mesh}.json").write_text(json.dumps(cells, indent=1))
    (out / f"{mesh}.md").write_text(markdown_table(cells))
    return cells


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "single")
