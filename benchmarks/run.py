"""Benchmark harness — one entry per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2,roofline]

Prints ``name,value,derived`` CSV lines and saves JSON artifacts.  The
serving-path jobs (decode / serve / spec) additionally write compact
machine-readable ``BENCH_<name>.json`` trajectory files at the repo root
(tok/s, J/token, acceptance) so the perf trajectory is tracked across PRs
— diff them in review like any other artifact, or print the full
git-SHA-stamped history table with::

    PYTHONPATH=src python -m benchmarks.run trajectory [bench ...]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
ART = ROOT / "artifacts" / "bench"

# headline perf-trajectory schema per serving-path job: every field must be
# a plain number so cross-PR diffs stay line-per-metric
TRAJECTORY = {
    "decode": lambda r: {
        "tok_per_s": r["tok_per_s"],
        "speedup_vs_per_token": r["speedup"],
        "j_per_token": r["j_per_token_cap100"],
        "j_per_token_deep_cap": r["j_per_token_deep_cap"],
    },
    "serve": lambda r: {
        "tok_per_s": r["tok_per_s"],
        "j_per_token": r["engine"]["j_per_token"],
        "j_per_token_ratio_vs_static": r["j_per_token_ratio"],
        "p50_latency_ratio_vs_static": r["p50_latency_ratio"],
    },
    "spec": lambda r: {
        "tok_per_s": r["tok_per_s"],
        "speedup_vs_plain": r["speedup"],
        "best_k": r["best_k"],
        "acceptance": r["acceptance"],
        "j_per_accepted_token": r["j_per_token"],
        "j_per_token_plain": r["j_per_token_plain"],
    },
    "prefix": lambda r: {
        "tok_per_s": r["tok_per_s"],
        "prefix_hit_rate": r["prefix_hit_rate"],
        "prefill_tokens_saved": r["prefill_tokens_saved"],
        "n_preemptions": r["n_preemptions"],
        "j_per_token_ratio_vs_plain": r["j_per_token_ratio"],
        "p50_latency_ratio_vs_plain": r["p50_latency_ratio"],
    },
    "chaos": lambda r: {
        "tok_per_s": r["tok_per_s"],
        "tokens_lost": r["tokens_lost"],
        "n_restores": r["n_restores"],
        "requests_requeued": r["requests_requeued"],
        "recovery_latency_s": r["recovery_latency_s"],
        "degraded_steps": r["degraded_steps"],
        "j_per_token_overhead_vs_faultfree": r["j_per_token_overhead"],
    },
    "kernel": lambda r: {
        "deep_speedup_vs_single_split": r["deep_speedup"],
        "deep_kv_len": r["deep_kv_len"],
        "deep_best_splits": r["deep_best_splits"],
        "shallow_auto_ratio": r["shallow_auto_ratio"],
        "max_exactness_err": r["max_exactness_err"],
    },
    "mla": lambda r: {
        "deep_speedup_vs_single_split": r["deep_speedup"],
        "deep_kv_len": r["deep_kv_len"],
        "deep_best_splits": r["deep_best_splits"],
        "kv_bytes_per_token": r["kv_bytes_per_token"],
        "kv_bytes_ratio_vs_gqa_eq": r["kv_bytes_ratio"],
        "transfer_j_per_token": r["transfer_j_per_token"],
        "max_exactness_err": r["max_exactness_err"],
    },
    "kvtier": lambda r: {
        "tok_per_s": r["tok_per_s"],
        "logical_pool_ratio": r["logical_pool_ratio"],
        "effective_hit_rate": r["effective_hit_rate"],
        "n_preemptions": r["n_preemptions"],
        "n_demotions": r["n_demotions"],
        "n_promotions": r["n_promotions"],
        "transfer_j": r["transfer_j"],
        "j_per_token_ratio_vs_evict": r["j_per_token_ratio"],
        "int8_oracle_max_err": r["int8_oracle_max_err"],
    },
}

# one human-readable headline CSV line per trajectory job (printed for CI
# logs next to the machine-readable artifact)
HEADLINE = {
    "decode": lambda r: (f"decode.tok_per_s,{r['tok_per_s']:.1f},"
                         f"fused loop, {r['speedup']:.2f}x over per-token "
                         "host loop (largest cache)"),
    "serve": lambda r: (f"serve.tok_per_s,{r['tok_per_s']:.1f},"
                        f"engine vs static: {r['j_per_token_ratio']:.2f}x "
                        f"J/token, {r['p50_latency_ratio']:.2f}x p50 latency"),
    "spec": lambda r: (f"spec.tok_per_s,{r['tok_per_s']:.1f},"
                       f"{r['speedup']:.2f}x over plain fused loop at "
                       f"K={r['best_k']} (replay acceptance "
                       f"{r['acceptance']:.2f})"),
    "prefix": lambda r: (f"prefix.hit_rate,{r['prefix_hit_rate']:.2f},"
                         f"{r['prefill_tokens_saved']} prefill tokens "
                         f"saved; {r['j_per_token_ratio']:.2f}x J/token, "
                         f"{r['p50_latency_ratio']:.2f}x p50 vs no-sharing"),
    "chaos": lambda r: (f"chaos.tokens_lost,{r['tokens_lost']},"
                        f"{r['n_restores']} crash-restores, "
                        f"{r['requests_requeued']} requeued, "
                        f"{r['degraded_steps']} capped steps; "
                        f"{r['j_per_token_overhead']:.2f}x J/token "
                        "vs fault-free"),
    "kernel": lambda r: (f"kernel.deep_speedup,{r['deep_speedup']:.2f},"
                         f"two-stage split-KV at KV={r['deep_kv_len']} "
                         f"(S={r['deep_best_splits']}); shallow auto ratio "
                         f"{r['shallow_auto_ratio']:.2f}x, exactness "
                         f"{r['max_exactness_err']:.1e}"),
    "mla": lambda r: (f"mla.deep_speedup,{r['deep_speedup']:.2f},"
                      f"latent split sweep at KV={r['deep_kv_len']} "
                      f"(S={r['deep_best_splits']}); "
                      f"{r['kv_bytes_ratio']:.1f}x KV bytes/token vs "
                      f"GQA-equivalent, exactness "
                      f"{r['max_exactness_err']:.1e}"),
    "kvtier": lambda r: (f"kvtier.j_per_token_ratio,"
                         f"{r['j_per_token_ratio']:.2f}x,"
                         f"{r['logical_pool_ratio']:.1f}x logical pool "
                         f"(int8 + host tier) vs evict-and-recompute; "
                         f"{r['n_demotions']} paged out, "
                         f"{r['n_preemptions']} preemptions"),
}


def _git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"], cwd=ROOT,
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        return "unknown"


def _write_trajectory(name: str, res: dict, quick: bool) -> None:
    if quick:
        # --quick shrinks the workload (CI smoke); overwriting the repo-root
        # artifact would make cross-PR diffs compare incommensurate runs
        print(f"{name}.trajectory,skipped,--quick runs do not rewrite "
              f"BENCH_{name}.json")
        return
    path = ROOT / f"BENCH_{name}.json"
    payload = {"bench": name, "git_sha": _git_sha(),
               **TRAJECTORY[name](res)}
    # a trajectory artifact without its commit stamp can't be diffed across
    # PRs — refuse to write one (regenerate from a git checkout instead)
    assert payload.get("git_sha"), f"BENCH_{name}.json payload missing git_sha"
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"{name}.trajectory,{path.name},machine-readable perf artifact")


def _bench_versions(path: pathlib.Path) -> list[dict]:
    """Every committed version of one BENCH_*.json, oldest first, plus the
    working-tree copy when it differs from HEAD's.  Each version carries
    the artifact's own ``git_sha`` stamp (the commit it was *generated*
    at), which is what the table keys on."""
    versions: list[dict] = []
    seen: set[str] = set()
    try:
        commits = subprocess.check_output(
            ["git", "log", "--reverse", "--format=%H", "--", path.name],
            cwd=ROOT, stderr=subprocess.DEVNULL).decode().split()
    except Exception:
        commits = []
    for commit in commits:
        try:
            blob = subprocess.check_output(
                ["git", "show", f"{commit}:{path.name}"], cwd=ROOT,
                stderr=subprocess.DEVNULL)
            rec = json.loads(blob)
        except Exception:
            continue
        sha = rec.get("git_sha", commit)
        if sha not in seen:
            seen.add(sha)
            versions.append(rec)
    try:
        rec = json.loads(path.read_text())
        if rec.get("git_sha") not in seen:
            versions.append(rec)
    except Exception:
        pass
    return versions


def trajectory_main(argv) -> int:
    """``benchmarks.run trajectory [bench ...]`` — print the perf history
    recorded by the BENCH_*.json artifacts as one table per bench: a row
    per generating commit (git-SHA-stamped), a column per metric.  The
    artifacts are committed with the code, so the table is exactly the
    cross-PR diff review sees, assembled from git history."""
    names = set(argv)
    files = sorted(ROOT.glob("BENCH_*.json"))
    if names:
        files = [f for f in files if f.stem[len("BENCH_"):] in names]
    if not files:
        print("no BENCH_*.json artifacts"
              + (f" matching {sorted(names)}" if names else "")
              + " — run the serving-path benchmarks first")
        return 1
    for path in files:
        bench = path.stem[len("BENCH_"):]
        versions = _bench_versions(path)
        if not versions:
            continue
        metrics = [k for k in versions[-1] if k not in ("bench", "git_sha")]
        print(f"# ---- {bench} trajectory ({len(versions)} recorded runs) "
              "----")
        head = "  ".join(f"{m:>24}" for m in metrics)
        print(f"{'git_sha':>10}  {head}")
        for rec in versions:
            row = "  ".join(
                f"{rec[m]:>24.6g}" if isinstance(rec.get(m), (int, float))
                else f"{str(rec.get(m, '-')):>24}" for m in metrics)
            print(f"{str(rec.get('git_sha', '?'))[:10]:>10}  {row}")
    return 0


def main(argv=None) -> int:
    if argv is None:
        import sys
        argv = sys.argv[1:]
    if argv and argv[0] == "trajectory":
        # subcommand, dispatched before the flat argparse: reads the
        # committed BENCH_*.json history instead of running anything
        return trajectory_main(argv[1:])
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced model set / steps (CI mode)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset, e.g. fig2,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (chaos_serve, ctrl_overhead, decode_kernel,
                            decode_throughput, fig2_energy, fig3_overhead,
                            fig4_capping, fig5_edxp, fig6_tradeoff, kv_tier,
                            prefix_cache, roofline, serve_engine, spec_decode)
    ART.mkdir(parents=True, exist_ok=True)
    jobs = {
        "fig2": lambda: fig2_energy.main(quick=args.quick),
        "fig3": lambda: fig3_overhead.main(quick=args.quick),
        "fig4": lambda: fig4_capping.main(quick=args.quick),
        "fig5": lambda: fig5_edxp.main(quick=args.quick),
        "fig6": lambda: fig6_tradeoff.main(quick=args.quick),
        "ctrl": lambda: ctrl_overhead.main(quick=args.quick),
        "decode": lambda: decode_throughput.main(quick=args.quick),
        "serve": lambda: serve_engine.main(quick=args.quick),
        "spec": lambda: spec_decode.main(quick=args.quick),
        "prefix": lambda: prefix_cache.main(quick=args.quick),
        "kvtier": lambda: kv_tier.main(quick=args.quick),
        "chaos": lambda: chaos_serve.main(quick=args.quick),
        "kernel": lambda: decode_kernel.main(quick=args.quick),
        "mla": lambda: decode_kernel.main_mla(quick=args.quick),
        "roofline": lambda: [roofline.main(m) for m in ("single", "multi")],
    }
    failures = 0
    for name, job in jobs.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# ---- {name} ----")
        try:
            res = job()
            (ART / f"{name}.json").write_text(json.dumps(res, default=str))
            if name in TRAJECTORY:
                _write_trajectory(name, res, args.quick)
            print(f"{name}.seconds,{time.time()-t0:.1f},ok")
            if name in HEADLINE:       # headline perf-trajectory line for CI
                print(HEADLINE[name](res))
        except Exception as e:                         # keep the harness alive
            failures += 1
            print(f"{name}.seconds,{time.time()-t0:.1f},"
                  f"FAIL {type(e).__name__}: {str(e)[:200]}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
