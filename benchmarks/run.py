"""Benchmark harness — one entry per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2,roofline]

Prints ``name,value,derived`` CSV lines and saves JSON artifacts.  The
serving-path jobs (decode / serve / spec) additionally write compact
machine-readable ``BENCH_<name>.json`` trajectory files at the repo root
(tok/s, J/token, acceptance) so the perf trajectory is tracked across PRs
— diff them in review like any other artifact.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
ART = ROOT / "artifacts" / "bench"

# headline perf-trajectory schema per serving-path job: every field must be
# a plain number so cross-PR diffs stay line-per-metric
TRAJECTORY = {
    "decode": lambda r: {
        "tok_per_s": r["tok_per_s"],
        "speedup_vs_per_token": r["speedup"],
        "j_per_token": r["j_per_token_cap100"],
        "j_per_token_deep_cap": r["j_per_token_deep_cap"],
    },
    "serve": lambda r: {
        "tok_per_s": r["tok_per_s"],
        "j_per_token": r["engine"]["j_per_token"],
        "j_per_token_ratio_vs_static": r["j_per_token_ratio"],
        "p50_latency_ratio_vs_static": r["p50_latency_ratio"],
    },
    "spec": lambda r: {
        "tok_per_s": r["tok_per_s"],
        "speedup_vs_plain": r["speedup"],
        "best_k": r["best_k"],
        "acceptance": r["acceptance"],
        "j_per_accepted_token": r["j_per_token"],
        "j_per_token_plain": r["j_per_token_plain"],
    },
}


def _write_trajectory(name: str, res: dict, quick: bool) -> None:
    if quick:
        # --quick shrinks the workload (CI smoke); overwriting the repo-root
        # artifact would make cross-PR diffs compare incommensurate runs
        print(f"{name}.trajectory,skipped,--quick runs do not rewrite "
              f"BENCH_{name}.json")
        return
    path = ROOT / f"BENCH_{name}.json"
    payload = {"bench": name, **TRAJECTORY[name](res)}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"{name}.trajectory,{path.name},machine-readable perf artifact")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced model set / steps (CI mode)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset, e.g. fig2,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (ctrl_overhead, decode_throughput, fig2_energy,
                            fig3_overhead, fig4_capping, fig5_edxp,
                            fig6_tradeoff, roofline, serve_engine,
                            spec_decode)
    ART.mkdir(parents=True, exist_ok=True)
    jobs = {
        "fig2": lambda: fig2_energy.main(quick=args.quick),
        "fig3": lambda: fig3_overhead.main(quick=args.quick),
        "fig4": lambda: fig4_capping.main(quick=args.quick),
        "fig5": lambda: fig5_edxp.main(quick=args.quick),
        "fig6": lambda: fig6_tradeoff.main(quick=args.quick),
        "ctrl": lambda: ctrl_overhead.main(quick=args.quick),
        "decode": lambda: decode_throughput.main(quick=args.quick),
        "serve": lambda: serve_engine.main(quick=args.quick),
        "spec": lambda: spec_decode.main(quick=args.quick),
        "roofline": lambda: [roofline.main(m) for m in ("single", "multi")],
    }
    failures = 0
    for name, job in jobs.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# ---- {name} ----")
        try:
            res = job()
            (ART / f"{name}.json").write_text(json.dumps(res, default=str))
            if name in TRAJECTORY:
                _write_trajectory(name, res, args.quick)
            print(f"{name}.seconds,{time.time()-t0:.1f},ok")
            if name == "decode":       # headline perf-trajectory line for CI
                print(f"decode.tok_per_s,{res['tok_per_s']:.1f},"
                      f"fused loop, {res['speedup']:.2f}x over per-token "
                      f"host loop (largest cache)")
            if name == "serve":        # continuous-batching trajectory
                print(f"serve.tok_per_s,{res['tok_per_s']:.1f},"
                      f"engine vs static: {res['j_per_token_ratio']:.2f}x "
                      f"J/token, {res['p50_latency_ratio']:.2f}x p50 latency")
            if name == "spec":         # speculative-decoding trajectory
                print(f"spec.tok_per_s,{res['tok_per_s']:.1f},"
                      f"{res['speedup']:.2f}x over plain fused loop at "
                      f"K={res['best_k']} (replay acceptance "
                      f"{res['acceptance']:.2f})")
        except Exception as e:                         # keep the harness alive
            failures += 1
            print(f"{name}.seconds,{time.time()-t0:.1f},"
                  f"FAIL {type(e).__name__}: {str(e)[:200]}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
