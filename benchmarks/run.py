"""Benchmark harness — one entry per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2,roofline]

Prints ``name,value,derived`` CSV lines (and saves JSON artifacts).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "bench"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced model set / steps (CI mode)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset, e.g. fig2,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (ctrl_overhead, decode_throughput, fig2_energy,
                            fig3_overhead, fig4_capping, fig5_edxp,
                            fig6_tradeoff, roofline, serve_engine)
    ART.mkdir(parents=True, exist_ok=True)
    jobs = {
        "fig2": lambda: fig2_energy.main(quick=args.quick),
        "fig3": lambda: fig3_overhead.main(quick=args.quick),
        "fig4": lambda: fig4_capping.main(quick=args.quick),
        "fig5": lambda: fig5_edxp.main(quick=args.quick),
        "fig6": lambda: fig6_tradeoff.main(quick=args.quick),
        "ctrl": lambda: ctrl_overhead.main(quick=args.quick),
        "decode": lambda: decode_throughput.main(quick=args.quick),
        "serve": lambda: serve_engine.main(quick=args.quick),
        "roofline": lambda: [roofline.main(m) for m in ("single", "multi")],
    }
    failures = 0
    for name, job in jobs.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# ---- {name} ----")
        try:
            res = job()
            (ART / f"{name}.json").write_text(json.dumps(res, default=str))
            print(f"{name}.seconds,{time.time()-t0:.1f},ok")
            if name == "decode":       # headline perf-trajectory line for CI
                print(f"decode.tok_per_s,{res['tok_per_s']:.1f},"
                      f"fused loop, {res['speedup']:.2f}x over per-token "
                      f"host loop (largest cache)")
            if name == "serve":        # continuous-batching trajectory
                print(f"serve.tok_per_s,{res['tok_per_s']:.1f},"
                      f"engine vs static: {res['j_per_token_ratio']:.2f}x "
                      f"J/token, {res['p50_latency_ratio']:.2f}x p50 latency")
        except Exception as e:                         # keep the harness alive
            failures += 1
            print(f"{name}.seconds,{time.time()-t0:.1f},"
                  f"FAIL {type(e).__name__}: {str(e)[:200]}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
