"""Paper Fig 6 — the headline result: mean energy saving vs delay across
all 16 models on both setups under the FROST-selected (ED^2P) caps.

Paper numbers: setup no.1 saves 26.4% energy at +6.9% time; setup no.2
saves 17.7% at +5.5%.  We report what the physics model + measured
per-model profiles produce, side by side with the paper's.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (SETUP1, SETUP2, epoch_quantities, profile_zoo)
from repro.core import BALANCED, CapProfiler


def run(models=None, steps: int = 12) -> dict:
    runs = profile_zoo(models, train_steps=steps)
    out = {}
    for setup_name, dev in (("setup1_rtx3080", SETUP1),
                            ("setup2_rtx3090", SETUP2)):
        rows = []
        for name, r in runs.items():
            wl = r.workload(samples_per_step=128)

            class W:
                def probe(self, cap, duration_s, dev=dev, wl=wl):
                    return dev.probe(wl, cap, duration_s)

            d = CapProfiler(W(), policy=BALANCED).run()
            e_cap, t_cap, _, _ = epoch_quantities(r, dev, cap=d.cap)
            e_100, t_100, _, _ = epoch_quantities(r, dev, cap=1.0)
            rows.append({"model": name, "cap": d.cap,
                         "energy_saving": 1 - e_cap / e_100,
                         "delay": t_cap / t_100 - 1,
                         "fit_ok": d.fit_accepted})
        out[setup_name] = {
            "rows": rows,
            "mean_energy_saving": float(np.mean([r["energy_saving"]
                                                 for r in rows])),
            "mean_delay": float(np.mean([r["delay"] for r in rows])),
        }
    out["paper"] = {"setup1": {"saving": 0.264, "delay": 0.069},
                    "setup2": {"saving": 0.177, "delay": 0.055}}
    return out


def main(quick: bool = False):
    res = run(models=["LeNet", "ResNet18", "MobileNetV2", "VGG16",
                      "DenseNet121", "EfficientNetB0"] if quick else None,
              steps=8 if quick else 12)
    for setup in ("setup1_rtx3080", "setup2_rtx3090"):
        m = res[setup]
        ref = res["paper"]["setup1" if "1" in setup else "setup2"]
        print(f"fig6.{setup},saving={m['mean_energy_saving']:.1%} "
              f"delay={m['mean_delay']:+.1%},"
              f"paper saving={ref['saving']:.1%} delay=+{ref['delay']:.1%}")
    return res


if __name__ == "__main__":
    main()
