"""Shared benchmark infrastructure.

Per CNN-zoo model we build a *measured* workload profile: FLOPs and HBM
bytes come from the jitted train step's ``cost_analysis()`` (CNNs have no
while loops, so XLA's numbers are exact here), wall time per step is
measured on this host, and the paper's GPU rigs are then driven by the
calibrated ``PowerCappedDevice`` model (DESIGN.md Sec 5) — physics-first,
not outcome-fitted: the paper's phenomenology has to *emerge*.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PowerCappedDevice, RTX_3080, RTX_3090,
                        WorkloadProfile)
from repro.data import CifarBatches
from repro.models.cnn import CNN_ZOO, cnn_loss
from repro.optim import OptimizerConfig, adamw_init, adamw_update

CIFAR_TRAIN_SIZE = 50_000


@dataclasses.dataclass
class ModelRun:
    name: str
    flops_per_step: float            # fwd+bwd, batch of `batch`
    bytes_per_step: float
    batch: int
    wall_s_per_step: float           # on this host (CPU) — Fig 3 baseline
    accuracy: float                  # after `train_steps` on synthetic CIFAR
    n_params: int

    def workload(self, samples_per_step: int | None = None) -> WorkloadProfile:
        return WorkloadProfile(
            name=self.name,
            flops_per_step=self.flops_per_step,
            hbm_bytes_per_step=self.bytes_per_step,
            samples_per_step=samples_per_step or self.batch,
        )


def _make_step(apply_fn, opt_cfg):
    def step(params, opt, images, labels):
        loss, grads = jax.value_and_grad(
            lambda p: cnn_loss(apply_fn, p, images, labels))(params)
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        return params, opt, loss
    return jax.jit(step)


_PROFILE_CACHE: dict = {}
_CACHE_DIR = __import__("pathlib").Path(__file__).resolve().parents[1] \
    / "artifacts" / "cnn_profiles"


def profile_cnn(name: str, *, batch: int = 32, train_steps: int = 12,
                eval_batches: int = 2, seed: int = 0,
                time_steps: int = 3) -> ModelRun:
    """Measure one zoo model: flops/bytes (XLA), wall time, short-train acc.

    Profiles are cached (in-process + on disk) — fig2/fig4/fig6 all profile
    the same zoo, and compiles dominate the cost on this host.
    """
    import json as _json
    key = (name, batch, train_steps, seed)
    if key in _PROFILE_CACHE:
        return _PROFILE_CACHE[key]
    fkey = _CACHE_DIR / f"{name}_{batch}_{train_steps}_{seed}.json"
    if fkey.exists():
        run = ModelRun(**_json.loads(fkey.read_text()))
        _PROFILE_CACHE[key] = run
        return run
    run = _profile_cnn_uncached(name, batch=batch, train_steps=train_steps,
                                eval_batches=eval_batches, seed=seed,
                                time_steps=time_steps)
    _PROFILE_CACHE[key] = run
    _CACHE_DIR.mkdir(parents=True, exist_ok=True)
    fkey.write_text(_json.dumps(dataclasses.asdict(run)))
    return run


def _profile_cnn_uncached(name: str, *, batch: int = 32, train_steps: int = 12,
                          eval_batches: int = 2, seed: int = 0,
                          time_steps: int = 3) -> ModelRun:
    init, apply = CNN_ZOO[name]
    params = init(jax.random.PRNGKey(seed))
    opt_cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=2,
                              total_steps=train_steps, weight_decay=0.0,
                              schedule="constant")
    opt = adamw_init(params, opt_cfg)
    data = CifarBatches(seed=seed, batch=batch)
    step = _make_step(apply, opt_cfg)

    x0, y0 = data.batch_at(0)
    lowered = step.lower(params, opt, jnp.asarray(x0), jnp.asarray(y0))
    compiled = lowered.compile()
    from repro.launch.hloparse import xla_cost
    cost = xla_cost(compiled)
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))

    # train briefly (synthetic CIFAR is separable: accuracy rises fast)
    t_acc = 0.0
    n_timed = 0
    for i in range(train_steps):
        x, y = data.batch_at(i)
        t0 = time.perf_counter()
        params, opt, loss = step(params, opt, jnp.asarray(x), jnp.asarray(y))
        jax.block_until_ready(loss)
        if i >= train_steps - time_steps:          # steady-state timing
            t_acc += time.perf_counter() - t0
            n_timed += 1

    # eval
    correct = total = 0
    for i in range(100, 100 + eval_batches):
        x, y = data.batch_at(i)
        logits = apply(params, jnp.asarray(x))
        correct += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(y)))
        total += y.size
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    return ModelRun(name=name, flops_per_step=flops, bytes_per_step=nbytes,
                    batch=batch, wall_s_per_step=t_acc / max(n_timed, 1),
                    accuracy=correct / total, n_params=n_params)


def epoch_quantities(run: ModelRun, device: PowerCappedDevice,
                     cap: float = 1.0, batch: int = 128):
    """(energy_J, time_s, mean_power_W, utilization) for ONE CIFAR epoch on
    the simulated rig, scaling the measured per-step profile to `batch`."""
    scale = batch / run.batch
    wl = WorkloadProfile(
        name=run.name,
        flops_per_step=run.flops_per_step * scale,
        hbm_bytes_per_step=run.bytes_per_step * scale,
        samples_per_step=batch,
    )
    est = device.estimate(wl, cap)
    steps = CIFAR_TRAIN_SIZE / batch
    return (est.energy_j * steps, est.step_time_s * steps, est.power_w,
            est.utilization)


def pearson(x, y) -> float:
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    if x.std() == 0 or y.std() == 0:
        return 0.0
    return float(np.corrcoef(x, y)[0, 1])


SETUP1 = PowerCappedDevice(RTX_3080)      # paper setup no.1
SETUP2 = PowerCappedDevice(RTX_3090)      # paper setup no.2

ZOO_ORDER = list(CNN_ZOO)


def profile_zoo(models=None, **kw) -> dict[str, ModelRun]:
    out = {}
    for name in (models or ZOO_ORDER):
        out[name] = profile_cnn(name, **kw)
    return out
