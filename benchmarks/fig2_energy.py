"""Paper Fig 2 — initial energy investigation over the 16-model zoo.

Validates three claims:
  (a) accuracy vs energy is WEAKLY correlated (paper r = 0.34),
  (b) energy vs training time is STRONGLY linear (paper r = 0.999),
  (c) GPU utilisation vs power saturates (~300 W on the RTX 3080): more
      power does not buy more utilisation past the knee.
"""
from __future__ import annotations

import json

from benchmarks.common import SETUP1, epoch_quantities, pearson, profile_zoo


def run(models=None, steps: int = 12, batch: int = 32) -> dict:
    runs = profile_zoo(models, train_steps=steps, batch=batch)
    rows = []
    for name, r in runs.items():
        e, t, p, u = epoch_quantities(r, SETUP1, cap=1.0)
        rows.append({"model": name, "accuracy": r.accuracy,
                     "epoch_energy_j": e, "epoch_time_s": t,
                     "power_w": p, "utilization": u,
                     "params_m": r.n_params / 1e6})
    acc = [r["accuracy"] for r in rows]
    energy = [r["epoch_energy_j"] for r in rows]
    times = [r["epoch_time_s"] for r in rows]
    utils = [r["utilization"] for r in rows]
    power = [r["power_w"] for r in rows]
    out = {
        "rows": rows,
        "r_accuracy_energy": pearson(acc, energy),
        "r_energy_time": pearson(energy, times),
        "r_power_utilization": pearson(power, utils),
        "paper": {"r_accuracy_energy": 0.34, "r_energy_time": 0.999},
    }
    return out


def main(quick: bool = False):
    res = run(models=["LeNet", "ResNet18", "MobileNetV2", "VGG16",
                      "GoogLeNet", "ShuffleNetV2"] if quick else None,
              steps=8 if quick else 12)
    for row in res["rows"]:
        print(f"fig2.{row['model']},{row['epoch_energy_j']:.0f},"
              f"J/epoch acc={row['accuracy']:.3f} "
              f"P={row['power_w']:.0f}W util={row['utilization']:.2f}")
    print(f"fig2.r_energy_time,{res['r_energy_time']:.4f},paper=0.999")
    print(f"fig2.r_accuracy_energy,{res['r_accuracy_energy']:.3f},paper=0.34")
    print(f"fig2.r_power_utilization,{res['r_power_utilization']:.3f},"
          f"saturating")
    return res


if __name__ == "__main__":
    json.dumps(main())
