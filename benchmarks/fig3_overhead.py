"""Paper Fig 3 — telemetry overhead.

Times inference over the CIFAR stand-in with (a) no meter, (b) FROST's
0.1 Hz sampler, (c) a CodeCarbon/Eco2AI-style 1 Hz sampler with heavier
per-sample analytics.  Claim: FROST ~= baseline; 1 Hz + analytics shows
measurable overhead on some models.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.data import CifarBatches
from repro.models.cnn import CNN_ZOO
from repro.telemetry.meters import CpuProcessMeter, DramMeter
from repro.telemetry.sampler import PowerSampler


class _HeavySampler(PowerSampler):
    """1 Hz tool with carbon-analytics baggage (CodeCarbon-style)."""

    def sample_once(self):
        s = super().sample_once()
        # emulate the extra per-sample work: geo/carbon lookups + serialization
        blob = {"watts": s.total_w, "intensity": 0.233, "region": "GB",
                "timestamp": time.time()}
        for _ in range(200):
            json.dumps(blob)
        return s


def _run_inference(apply_fn, params, batches, sampler=None):
    t0 = time.perf_counter()
    if sampler is None:
        for x in batches:
            jax.block_until_ready(apply_fn(params, x))
    else:
        with sampler:
            for x in batches:
                jax.block_until_ready(apply_fn(params, x))
    return time.perf_counter() - t0


def run(models=("LeNet", "ResNet18", "MobileNetV2", "VGG16"),
        n_batches: int = 24, batch: int = 64) -> dict:
    data = CifarBatches(seed=0, batch=batch)
    batches = [jnp.asarray(data.batch_at(i)[0]) for i in range(n_batches)]
    meters = lambda: {"cpu": CpuProcessMeter(), "dram": DramMeter(4, 16)}
    rows = []
    for name in models:
        init, apply = CNN_ZOO[name]
        params = init(jax.random.PRNGKey(0))
        jitted = jax.jit(apply)
        jax.block_until_ready(jitted(params, batches[0]))   # compile
        t_base = _run_inference(jitted, params, batches)
        t_frost = _run_inference(jitted, params, batches,
                                 PowerSampler(meters(), rate_hz=0.1))
        t_heavy = _run_inference(jitted, params, batches,
                                 _HeavySampler(meters(), rate_hz=1.0))
        rows.append({"model": name, "baseline_s": t_base,
                     "frost_s": t_frost, "heavy_1hz_s": t_heavy,
                     "frost_overhead": t_frost / t_base - 1,
                     "heavy_overhead": t_heavy / t_base - 1})
    return {"rows": rows}


def main(quick: bool = False):
    res = run(models=("LeNet", "ResNet18") if quick else
              ("LeNet", "ResNet18", "MobileNetV2", "VGG16"),
              n_batches=10 if quick else 24)
    for r in res["rows"]:
        print(f"fig3.{r['model']},{r['baseline_s']*1e3:.0f}ms,"
              f"frost={r['frost_overhead']:+.1%} "
              f"heavy1hz={r['heavy_overhead']:+.1%}")
    mean_frost = sum(r["frost_overhead"] for r in res["rows"]) / len(res["rows"])
    print(f"fig3.mean_frost_overhead,{mean_frost:.4f},paper~=0")
    return res


if __name__ == "__main__":
    main()
